"""Step builders: train_step / prefill_step / serve_step per architecture,
plus ``input_specs`` (ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, no device allocation).

Every builder wraps its forward in ``engine_scope(cfg)``: one ambient
engine policy (ModelConfig.engine) covers both halves of the dual-engine
overlay — spike matmuls (dense vs block-sparse) *and* spiking attention
(jnp vs MXU kernel vs popcount) — so models carry no engine plumbing and
a single config knob flips the whole hot path (DESIGN.md §4).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunShape
from repro.core.engine import engine_scope
from repro.models import registry
from repro.optim import Optimizer

# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_struct(cfg: ModelConfig, shape: RunShape) -> Dict[str, Any]:
    """Abstract batch for forward/train at this run shape."""
    b = shape.global_batch
    s = shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    if cfg.family == "vlm":
        n_patch = cfg.frontend.num_embeds
        return {"tokens": _sds((b, s - n_patch), jnp.int32),
                "patch_embeds": _sds((b, n_patch, cfg.frontend.embed_dim),
                                     dt)}
    if cfg.family == "encdec":
        return {"tokens": _sds((b, s), jnp.int32),
                "audio_embeds": _sds((b, cfg.encoder_seq, cfg.d_model), dt)}
    if cfg.family in ("spikingformer", "cifarnet"):
        v = cfg.vision
        return {"images": _sds((b, v.img_size, v.img_size, v.in_channels),
                               dt),
                "labels": _sds((b,), jnp.int32)}
    return {"tokens": _sds((b, s), jnp.int32)}


def cache_struct(cfg: ModelConfig, shape: RunShape):
    """Abstract decode cache (eval_shape over init_cache — no allocation)."""
    fn = functools.partial(registry.init_cache, cfg, shape.global_batch,
                           shape.seq_len)
    return jax.eval_shape(fn)


def decode_inputs_struct(cfg: ModelConfig, shape: RunShape):
    tokens = _sds((shape.global_batch, 1), jnp.int32)
    pos = _sds((), jnp.int32)
    return cache_struct(cfg, shape), tokens, pos


def abstract_params(cfg: ModelConfig, seed: int = 0):
    return jax.eval_shape(lambda: registry.init(cfg, jax.random.PRNGKey(seed)))


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def softmax_xent(logits: jax.Array, targets: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return nll.mean()


def loss_from_forward(cfg: ModelConfig, logits, batch) -> jax.Array:
    if cfg.family in ("spikingformer", "cifarnet"):
        return softmax_xent(logits, batch["labels"])
    tokens = batch["tokens"]
    if cfg.family == "vlm":
        n_patch = cfg.frontend.num_embeds
        preds = logits[:, n_patch - 1:-1]
        return softmax_xent(preds, tokens)
    return softmax_xent(logits[:, :-1], tokens[:, 1:])


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def build_train_step(cfg: ModelConfig, optimizer: Optimizer,
                     compress: bool = False,
                     qat: Optional[str] = None) -> Callable:
    """(params, opt_state, step, batch[, model_state]) ->
    (params, opt_state, step+1, metrics[, model_state]).

    ``qat``: 'int8' | 'int4' enables quantization-aware training — the
    loss sees fake-quantized linears (repro.quant.qat, STE gradients to
    the fp32 masters), so a post-training ``quantize_tree`` serves the
    exact weights the loss optimized."""
    stateful = cfg.family in ("spikingformer", "cifarnet")
    if qat is not None:
        from repro.quant.qat import fake_quant_tree
        fq = functools.partial(fake_quant_tree, dtype=qat)
    else:
        fq = lambda p: p

    if stateful:
        def train_step(params, opt_state, step, batch, model_state):
            def loss_fn(p):
                with engine_scope(cfg):
                    logits, aux = registry.forward(fq(p), cfg, batch,
                                                   train=True,
                                                   state=model_state)
                return loss_from_forward(cfg, logits, batch), aux
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params)
            new_params, new_opt = optimizer.update(grads, opt_state, params,
                                                   step)
            metrics = {"loss": loss, "grad_norm": new_opt["grad_norm"],
                       "fire_rate": aux.get("fire_rate", jnp.zeros(()))}
            return new_params, new_opt, step + 1, metrics, aux["state"]
        return train_step

    def train_step(params, opt_state, step, batch):
        def loss_fn(p):
            with engine_scope(cfg):
                logits, aux = registry.forward(fq(p), cfg, batch, train=True)
            loss = loss_from_forward(cfg, logits, batch)
            if "moe_aux" in aux:
                loss = loss + aux["moe_aux"]
            return loss, aux
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        if compress:
            from repro.optim import compressed_gradients
            err = opt_state.get("compress_err")
            grads, new_err = compressed_gradients(grads, err)
        new_params, new_opt = optimizer.update(grads, opt_state, params, step)
        if compress:
            new_opt["compress_err"] = new_err
        metrics = {"loss": loss, "grad_norm": new_opt["grad_norm"]}
        if "moe_aux" in aux:
            metrics["moe_aux"] = aux["moe_aux"]
        return new_params, new_opt, step + 1, metrics

    return train_step


def build_prefill_step(cfg: ModelConfig) -> Callable:
    """Inference forward over the full sequence (logits only; the KV cache
    materialization for chunked prefill->decode handoff is exercised by
    serve.py at host scale)."""
    def prefill_step(params, batch):
        with engine_scope(cfg):
            logits, _ = registry.forward(params, cfg, batch, train=False)
        return logits
    return prefill_step


def build_serve_step(cfg: ModelConfig) -> Callable:
    """One decode step: (params, cache, tokens (B,1), pos) ->
    (next_token_logits, new_cache)."""
    def serve_step(params, cache, tokens, pos):
        with engine_scope(cfg):
            logits, new_cache = registry.decode_step(params, cfg, cache,
                                                     tokens, pos)
        return logits, new_cache
    return serve_step


def build_batched_serve_step(cfg: ModelConfig) -> Callable:
    """Continuous-batching orchestrator step (slotted-decode families):
    (params, cache, tokens (B,C), pos (B,), n_tok (B,)) ->
    (logits (B,C,V), new_cache). Every slot runs its own timeline — pos is
    per-slot, and a row's tokens beyond n_tok are padding (a decode slot
    rides a chunked-prefill wave contributing a single real token)."""
    def serve_step(params, cache, tokens, pos, n_tok):
        with engine_scope(cfg):
            logits, new_cache = registry.decode_step(params, cfg, cache,
                                                     tokens, pos,
                                                     n_tok=n_tok)
        return logits, new_cache
    return serve_step


def step_for_shape(cfg: ModelConfig, shape: RunShape,
                   optimizer: Optional[Optimizer] = None) -> Callable:
    if shape.mode == "train":
        assert optimizer is not None
        return build_train_step(cfg, optimizer)
    if shape.mode == "prefill":
        return build_prefill_step(cfg)
    return build_serve_step(cfg)
