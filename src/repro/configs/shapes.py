"""Assigned input-shape sets (LM transformer shapes: seq_len x global_batch).

decode_* / long_* lower ``serve_step`` (one token against a seq_len KV
cache), not ``train_step``. long_500k runs only for sub-quadratic archs
(SWA / local:global / SSM / hybrid); pure full-attention archs skip it
(registry.NO_LONG_CONTEXT, DESIGN.md §5).
"""
from .base import RunShape

TRAIN_4K = RunShape("train_4k", seq_len=4096, global_batch=256, mode="train")
PREFILL_32K = RunShape("prefill_32k", seq_len=32768, global_batch=32,
                       mode="prefill")
DECODE_32K = RunShape("decode_32k", seq_len=32768, global_batch=128,
                      mode="decode")
LONG_500K = RunShape("long_500k", seq_len=524288, global_batch=1,
                     mode="decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
LM_SHAPE_NAMES = tuple(SHAPES)
