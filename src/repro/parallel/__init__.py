from . import sharding
