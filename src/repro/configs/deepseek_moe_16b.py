"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (MHA kv=16) d_ff=1408
vocab=102400, MoE 64 routed top-6 + 2 shared, fine-grained experts, first
layer dense (d_ff 10944) [arXiv:2401.06066; hf]."""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=102400,
    attn_type="full", act="silu", gated=True, rope_theta=10000.0,
    moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408, num_shared=2,
                  first_k_dense=1, first_dense_ff=10944,
                  capacity_factor=1.25),
)

SMOKE = CONFIG.replace(
    num_layers=3, d_model=96, num_heads=4, num_kv_heads=4, head_dim=24,
    d_ff=64, vocab_size=512, dtype="float32", remat=False,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=48, num_shared=2,
                  first_k_dense=1, first_dense_ff=192,
                  capacity_factor=8.0))
