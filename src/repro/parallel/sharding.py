"""Logical-axis sharding rules (MaxText-style) + param-tree spec assignment.

Models annotate activations with *logical* axis names via :func:`constrain`;
a rules table maps logical names to mesh axes. Parameters get their
PartitionSpec from path-pattern rules per family (see :func:`param_specs`).

The production meshes (launch/mesh.py) are
  single-pod : (data=16, model=16)
  multi-pod  : (pod=2, data=16, model=16)

Default logical rules:
  batch   -> ('pod', 'data')   (DP; pod folds into DP)
  fsdp    -> 'data'            (param/optimizer FSDP shard axis)
  model   -> 'model'           (TP: heads / d_ff / vocab / experts)
  seq     -> None              (sequence usually replicated; SP shards it)
"""
from __future__ import annotations

import re
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


DEFAULT_RULES: Dict[str, Any] = {
    "batch": ("pod", "data"),
    "fsdp": "data",
    "model": "model",
    "seq": None,
    "seq_shard": "data",   # sequence-parallel shard (long-context KV)
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "d_ff": "model",
    "vocab": "model",
    "experts": "model",
}


def set_rules(rules: Optional[Dict[str, Any]]) -> None:
    _state.rules = rules


def get_rules() -> Optional[Dict[str, Any]]:
    return getattr(_state, "rules", None)


class use_rules:
    """Context manager installing logical->mesh axis rules."""

    def __init__(self, rules: Optional[Dict[str, Any]]):
        self.rules = rules

    def __enter__(self):
        self.prev = get_rules()
        set_rules(self.rules)
        return self.rules

    def __exit__(self, *exc):
        set_rules(self.prev)


def _mesh_axes(mesh: Optional[Mesh]) -> Tuple[str, ...]:
    if mesh is not None:
        return tuple(mesh.axis_names)
    # jax.sharding.get_abstract_mesh only exists on newer jax; on the
    # pinned 0.4.x there is no ambient abstract mesh to consult.
    get_abstract_mesh = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract_mesh is None:
        return ()
    env = get_abstract_mesh()
    return tuple(env.axis_names) if env is not None else ()


def logical_spec(names: Sequence[Optional[str]],
                 rules: Optional[Dict[str, Any]] = None,
                 mesh: Optional[Mesh] = None) -> P:
    """Translate logical axis names to a PartitionSpec under ``rules``.

    Mesh axes that do not exist in the active mesh are dropped (so a
    single-pod mesh silently ignores the 'pod' component), and an axis used
    twice keeps only its first occurrence (PartitionSpec validity).
    """
    rules = rules if rules is not None else (get_rules() or {})
    avail = set(_mesh_axes(mesh))
    used: set = set()
    parts = []
    for name in names:
        axes = rules.get(name) if name else None
        if axes is None:
            parts.append(None)
            continue
        if isinstance(axes, str):
            axes = (axes,)
        axes = tuple(a for a in axes if (not avail or a in avail) and a not in used)
        used.update(axes)
        parts.append(axes if len(axes) > 1 else (axes[0] if axes else None))
    return P(*parts)  # keep positional trailing Nones


def rules_for_mesh(mesh: Mesh, **overrides) -> Dict[str, Any]:
    """DEFAULT_RULES bound to a concrete mesh (constrain() then emits
    NamedShardings — no ambient mesh context needed)."""
    rules = dict(DEFAULT_RULES, **overrides)
    rules["_mesh"] = mesh
    return rules


def constrain(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without rules.

    Specs are fitted to the value's shape (axes that don't divide a dim
    are dropped), so the same model code works for batch=256 and batch=1.
    """
    rules = get_rules()
    if rules is None:
        return x
    mesh = rules.get("_mesh")
    spec = logical_spec(names, rules, mesh=mesh)
    spec = fit_spec_to_shape(spec, x.shape, mesh)
    try:
        if mesh is not None:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, spec))
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x  # outside mesh context (unit tests on CPU)


# ---------------------------------------------------------------------------
# Parameter spec assignment by path patterns
# ---------------------------------------------------------------------------

def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _axis_sizes(mesh: Optional[Mesh]) -> Dict[str, int]:
    if mesh is None:
        return {}
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def fit_spec_to_shape(spec: P, shape, mesh: Optional[Mesh]) -> P:
    """Right-align a spec to ``shape`` and drop mesh axes that don't divide
    the dimension (e.g. vocab=51865 can't shard 16-way; 25 heads can't
    shard over model=16 — they fall back to replicated on that dim)."""
    ndim = len(shape)
    parts = list(spec)
    if len(parts) > ndim:
        parts = parts[len(parts) - ndim:]
    if len(parts) < ndim:
        parts = [None] * (ndim - len(parts)) + parts
    sizes = _axis_sizes(mesh)
    out = []
    for dim, part in zip(shape, parts):
        if part is None:
            out.append(None)
            continue
        axes = (part,) if isinstance(part, str) else tuple(part)
        kept, prod = [], 1
        for a in axes:
            n = sizes.get(a, None)
            if n is None and sizes:
                continue
            n = n or 1
            if dim % (prod * n) == 0:
                kept.append(a)
                prod *= n
        out.append(tuple(kept) if len(kept) > 1 else
                   (kept[0] if kept else None))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def param_specs(params: Any, pattern_rules: Sequence[Tuple[str, P]],
                default: P = P(), mesh: Optional[Mesh] = None) -> Any:
    """Map a param pytree to PartitionSpecs via ordered regex path rules.

    ``pattern_rules``: list of (regex, PartitionSpec); first match wins.
    Specs are right-aligned to each leaf's rank (scan-stacked params add a
    leading layer axis that stays unsharded) and validated against ``mesh``
    for divisibility (non-dividing axes are dropped per-dimension).
    """
    compiled = [(re.compile(rx), spec) for rx, spec in pattern_rules]

    def assign(path, leaf):
        ps = _path_str(path)
        shape = getattr(leaf, "shape", ())
        for rx, spec in compiled:
            if rx.search(ps):
                return fit_spec_to_shape(spec, shape, mesh)
        return fit_spec_to_shape(default, shape, mesh)

    return jax.tree_util.tree_map_with_path(assign, params)


def shard_put(tree: Any, spec_tree: Any, mesh: Mesh) -> Any:
    """device_put a pytree onto ``mesh`` following a PartitionSpec tree
    (the host->mesh hand-off for serve: params and cache move once, the
    jitted step then keeps them resident in their shards)."""
    return jax.tree_util.tree_map(
        lambda s, x: jax.device_put(x, NamedSharding(mesh, s)),
        spec_tree, tree,
        is_leaf=lambda s: isinstance(s, P))


def named_sharding_tree(specs: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, filter_spec_for_mesh(s, mesh)), specs,
        is_leaf=lambda s: isinstance(s, P))


def filter_spec_for_mesh(spec: P, mesh: Mesh) -> P:
    """Drop mesh-axis references that don't exist in ``mesh``."""
    avail = set(mesh.axis_names)
    parts = []
    for part in spec:
        if part is None:
            parts.append(None)
        elif isinstance(part, str):
            parts.append(part if part in avail else None)
        else:
            kept = tuple(a for a in part if a in avail)
            parts.append(kept if len(kept) > 1 else (kept[0] if kept else None))
    return P(*parts)
