"""spikingformer-lm — a token-domain Spikingformer: the transformer family
in spiking mode (LIF activations over T_s steps, binary causal SSA).

This is the serve-path workload of the dual-engine overlay: prefill runs
the binary engine over the full prompt (engine-dispatched SSA), decode
runs token-by-token against a *bit-packed* spike KV cache (uint32 words,
the paper's 32x spike-RAM compression — `models/transformer.init_cache`
with `engine.packed_kv`), scoring with AND-PopCount. The shape mirrors
spikingformer-4-256 lifted to an LM (same blocks/width, GPT-2-ish vocab).
"""
from repro.core.engine import EngineConfig
from repro.core.spiking import SpikingConfig
from .base import ModelConfig

CONFIG = ModelConfig(
    name="spikingformer-lm", family="dense",
    num_layers=4, d_model=256, num_heads=8, num_kv_heads=8, head_dim=32,
    d_ff=1024, vocab_size=32000,
    attn_type="full", act="relu2", gated=False,
    spiking=SpikingConfig(time_steps=4),
    # binary='auto': full-size shapes clear the flop floor and run the
    # fused MXU kernel; packed_kv turns on the popcount decode cache.
    engine=EngineConfig(mode="auto", sparse="auto", overlap="auto"),
)

# head_dim=16 deliberately non-word-sized: the packed KV cache pads the
# final uint32 word with zero bits (AND-PopCount neutral), pinning the
# non-divisible packing path in every smoke run.
SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=64,
    spiking=SpikingConfig(time_steps=2), dtype="float32", remat=False)
