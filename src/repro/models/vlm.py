"""LLaVA-NeXT-style VLM: mistral-7b backbone + stubbed anyres vision
frontend (llava-next-mistral-7b).

Per the assignment the vision tower is a STUB: ``input_specs()`` provides
precomputed patch embeddings ``(B, P, vision_dim)`` (anyres tiling happens
upstream). The mm projector (2-layer GELU MLP, the real trainable part of
LLaVA's adapter) IS implemented. Patch tokens are prepended to the text
sequence; total sequence length is the shape's ``seq_len``.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import nn, transformer


def init(cfg: ModelConfig, key) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.dtype)
    k_backbone, k_proj = jax.random.split(key)
    fr = cfg.frontend
    ks = jax.random.split(k_proj, fr.projector_layers)
    proj = [nn.linear_init(ks[0], fr.embed_dim, cfg.d_model, bias=True,
                           dtype=dt)]
    for i in range(1, fr.projector_layers):
        proj.append(nn.linear_init(ks[i], cfg.d_model, cfg.d_model, bias=True,
                                   dtype=dt))
    params = transformer.init(cfg, k_backbone)
    params["mm_projector"] = proj
    return params


def project_patches(params, patch_embeds):
    x = patch_embeds
    for i, p in enumerate(params["mm_projector"]):
        if i:
            x = jax.nn.gelu(x)
        x = nn.linear(p, x)
    return x


def forward(params, cfg: ModelConfig, batch, *, train: bool = False):
    """batch: {'tokens': (B, S_text), 'patch_embeds': (B, P, vision_dim)}.

    Sequence = [projected patches ; text embeddings], length P + S_text.
    """
    vis = project_patches(params, batch["patch_embeds"])
    txt = nn.embed(params["embed"], batch["tokens"])
    embeds = jnp.concatenate([vis.astype(txt.dtype), txt], axis=1)
    return transformer.forward(params, cfg, batch, train=train,
                               inputs_embeds=embeds)


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int,
               batch=None, params=None, chunk_headroom: int = 0):
    return transformer.init_cache(cfg, batch_size, max_len,
                                  chunk_headroom=chunk_headroom)


def decode_step(params, cfg: ModelConfig, cache, tokens, pos, n_tok=None):
    """Text-token continuation after a multimodal prefill."""
    return transformer.decode_step(params, cfg, cache, tokens, pos,
                                   n_tok=n_tok)


# cache layout is the transformer's -> same slot-invalidation tag write
invalidate_slots = transformer.invalidate_slots
