"""Batched LM serving with continuous batching (deliverable b, serving
kind): submit N requests into a slot-limited decode server; finished
sequences free slots for queued requests.

    PYTHONPATH=src python examples/serve_lm.py --arch h2o-danube-3-4b
"""
import sys

sys.path.insert(0, "src")

from repro.launch.serve import main  # noqa: E402

if __name__ == "__main__":
    main()
