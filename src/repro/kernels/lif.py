"""Fused LIF membrane update over T_s time steps.

FireFly-T pipelines membrane accumulation across output channels so the
neuronal-dynamics module shrinks to a (P_Fx x P_Ts) grid. The TPU analogue:
keep the membrane in a VMEM scratch across the in-kernel time loop so HBM
sees the input currents once and the output spikes once (instead of T
round-trips through a lax.scan over whole tensors). VPU-bound, fuses the
decay/threshold/reset chain.

Layout: currents (T, M, D) -> spikes (T, M, D); grid (nM, nD); the kernel
holds a (block_m, block_d) fp32 membrane in VMEM scratch and unrolls T.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(i_ref, o_ref, u_scratch, *, t_steps: int, decay: float,
            v_th: float, soft_reset: bool):
    u_scratch[...] = jnp.zeros_like(u_scratch)
    for t in range(t_steps):
        u = decay * u_scratch[...] + i_ref[t].astype(jnp.float32)
        s = (u >= v_th).astype(jnp.float32)
        if soft_reset:
            u = u - s * v_th
        else:
            u = u * (1.0 - s)
        u_scratch[...] = u
        o_ref[t] = s.astype(o_ref.dtype)


def lif_forward(currents: jax.Array, *, decay: float, v_th: float = 1.0,
                soft_reset: bool = False,
                block_m: int = 256, block_d: int = 512,
                interpret: Optional[bool] = None) -> jax.Array:
    """currents: (T, M, D) -> spikes (T, M, D) (same dtype)."""
    t, m, d = currents.shape
    block_m = min(block_m, m)
    block_d = min(block_d, d)
    assert m % block_m == 0 and d % block_d == 0, (m, d, block_m, block_d)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    grid = (m // block_m, d // block_d)
    return pl.pallas_call(
        functools.partial(_kernel, t_steps=t, decay=decay, v_th=v_th,
                          soft_reset=soft_reset),
        grid=grid,
        in_specs=[pl.BlockSpec((t, block_m, block_d),
                               lambda mi, di: (0, mi, di))],
        out_specs=pl.BlockSpec((t, block_m, block_d),
                               lambda mi, di: (0, mi, di)),
        out_shape=jax.ShapeDtypeStruct((t, m, d), currents.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_d), jnp.float32)],
        interpret=interpret,
    )(currents)
