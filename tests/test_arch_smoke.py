"""Per-architecture smoke tests: every assigned arch (+ paper models)
instantiates its REDUCED config and runs one forward/train step on CPU,
asserting output shapes + no NaNs. Decode smoke for LM families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.launch import steps as steps_lib
from repro.models import registry
from repro.optim import adamw

B, S = 2, 16


def _batch(cfg):
    rng = np.random.default_rng(0)
    if cfg.family in ("spikingformer", "cifarnet"):
        v = cfg.vision
        return {"images": jnp.asarray(rng.random(
            (B, v.img_size, v.img_size, v.in_channels), np.float32)),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, B),
                                  jnp.int32)}
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(rng.normal(
            0, 0.1, (B, cfg.frontend.num_embeds,
                     cfg.frontend.embed_dim)).astype(np.float32))
    if cfg.family == "encdec":
        batch["audio_embeds"] = jnp.asarray(rng.normal(
            0, 0.1, (B, cfg.encoder_seq, cfg.d_model)).astype(np.float32))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward(arch):
    cfg = get_config(arch, smoke=True)
    params = registry.init(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    state = registry.init_state(cfg)
    kw = {"state": state} if state is not None else {}
    logits, aux = registry.forward(params, cfg, batch, train=False, **kw)
    if cfg.family in ("spikingformer", "cifarnet"):
        assert logits.shape == (B, cfg.vocab_size)
    elif cfg.family == "vlm":
        assert logits.shape == (B, S + cfg.frontend.num_embeds,
                                cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = registry.init(cfg, jax.random.PRNGKey(0))
    opt = adamw(1e-3)
    opt_state = opt.init(params)
    batch = _batch(cfg)
    step_fn = steps_lib.build_train_step(cfg, opt)
    if cfg.family in ("spikingformer", "cifarnet"):
        model_state = registry.init_state(cfg)
        p2, o2, s2, metrics, _ = jax.jit(step_fn)(
            params, opt_state, jnp.asarray(0), batch, model_state)
    else:
        p2, o2, s2, metrics = jax.jit(step_fn)(
            params, opt_state, jnp.asarray(0), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually changed
    moved = jax.tree_util.tree_reduce(
        lambda acc, ab: acc + float(jnp.abs(ab).sum()),
        jax.tree_util.tree_map(lambda a, b: (a - b).astype(jnp.float32),
                               p2, params), 0.0)
    assert moved > 0


@pytest.mark.parametrize("arch", [a for a in ALL_ARCHS
                                  if a not in ("spikingformer-4-256",
                                               "spikingformer-8-512",
                                               "cifarnet")])
def test_smoke_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    params = registry.init(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    cache = registry.init_cache(cfg, B, 32, batch=batch, params=params)
    tok = batch["tokens"][:, :1]
    logits, new_cache = jax.jit(
        steps_lib.build_serve_step(cfg))(params, cache, tok,
                                         jnp.asarray(0, jnp.int32))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert jax.tree_util.tree_structure(new_cache) == \
        jax.tree_util.tree_structure(cache)


@pytest.mark.parametrize("arch", ["nemotron-4-15b", "gemma3-12b",
                                  "kimi-k2-1t-a32b"])
def test_full_config_param_count(arch):
    """Published configs have the right parameter scale (abstract only)."""
    cfg = get_config(arch)
    abstract = steps_lib.abstract_params(cfg)
    n = sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(abstract))
    expected = {"nemotron-4-15b": 15e9, "gemma3-12b": 12e9,
                "kimi-k2-1t-a32b": 1.0e12}[arch]
    assert 0.65 * expected < n < 1.45 * expected, f"{arch}: {n/1e9:.1f}B"
