"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per bench plus the full row dumps,
and (when dry-run artifacts exist) the roofline table.

Usage: PYTHONPATH=src python -m benchmarks.run [--fast]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, "src")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the model-training sparsity bench")
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: --fast + --skip-roofline")
    args = ap.parse_args()
    if args.smoke:
        args.fast = True
        args.skip_roofline = True

    import dual_engine_bench
    import paper_figures as pf
    import quant_bench

    quant_extras = []

    def quant_fn():
        rows, extras = quant_bench.bench(fast=args.fast)
        quant_extras.append((rows, extras))
        return rows, extras["derived"]

    benches = [
        ("fig12_decoder", pf.fig12_decoder),
        ("fig13_balance", pf.fig13_balance),
        ("table4_comparison", pf.table4_comparison),
        ("table56_resources", pf.table56_resources),
        ("fig5_pipeline", pf.fig5_pipeline),
        ("kernels", pf.kernels_bench),
        ("dual_engine", lambda: dual_engine_bench.bench(fast=args.fast)),
        ("quant", quant_fn),
    ]
    if not args.fast:
        benches.insert(0, ("fig11_sparsity", pf.fig11_sparsity))

    print("name,us_per_call,derived")
    all_rows = {}
    for name, fn in benches:
        t0 = time.perf_counter()
        rows, derived = fn()
        us = (time.perf_counter() - t0) * 1e6
        all_rows[name] = {"rows": rows, "derived": derived}
        print(f"{name},{us:.0f},\"{json.dumps(derived)}\"")
    os.makedirs("artifacts", exist_ok=True)
    with open("artifacts/bench_results.json", "w") as f:
        json.dump(all_rows, f, indent=1, default=str)
    # standalone dual-engine artifact (matmul + attention sweeps): same
    # layout dual_engine_bench.py --out writes, kept current by every run
    de = all_rows["dual_engine"]
    with open("artifacts/dual_engine_bench.json", "w") as f:
        json.dump(dual_engine_bench.to_blob(de["rows"], de["derived"]),
                  f, indent=1)
    # standalone quantization artifact (kernel sweep + measured footprint
    # + PTQ calibration): same layout quant_bench.py --out writes
    q_rows, q_extras = quant_extras[0]
    with open("artifacts/quant_bench.json", "w") as f:
        json.dump(quant_bench.to_blob(q_rows, q_extras), f, indent=1)

    print("\n== row dumps ==")
    for name, blob in all_rows.items():
        for row in blob["rows"]:
            print(json.dumps(row))

    if not args.skip_roofline and os.path.isdir("artifacts/dryrun"):
        print("\n== roofline (single-pod, per device) ==")
        import roofline
        rows = roofline.full_table()
        with open("artifacts/roofline.json", "w") as f:
            json.dump(rows, f, indent=1)
        print(roofline.render(rows))


if __name__ == "__main__":
    main()
