"""Fixed-seed stand-in for the `hypothesis` subset this suite uses.

The container does not ship `hypothesis`; the property tests only need
``@settings(max_examples=N, deadline=None)``, ``@given(...)`` and the
``st.integers / st.floats / st.lists / st.sampled_from / st.booleans``
strategies. This shim replays a deterministic example stream (seeded per
test name, boundary values first) so the tests collect and run anywhere.
If the real package is installed, the test modules import it instead.
"""
from __future__ import annotations

import functools
import inspect
import random
import zlib


class _Strategy:
    def example(self, i: int, rng: random.Random):
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, min_value, max_value):
        self.lo, self.hi = int(min_value), int(max_value)

    def example(self, i, rng):
        if i == 0:
            return self.lo
        if i == 1:
            return self.hi
        return rng.randint(self.lo, self.hi)


class _Floats(_Strategy):
    def __init__(self, min_value, max_value):
        self.lo, self.hi = float(min_value), float(max_value)

    def example(self, i, rng):
        if i == 0:
            return self.lo
        if i == 1:
            return self.hi
        return rng.uniform(self.lo, self.hi)


class _Booleans(_Strategy):
    def example(self, i, rng):
        if i < 2:
            return bool(i)
        return rng.random() < 0.5


class _SampledFrom(_Strategy):
    def __init__(self, elements):
        self.elements = list(elements)

    def example(self, i, rng):
        if i < len(self.elements):
            return self.elements[i]
        return rng.choice(self.elements)


class _Lists(_Strategy):
    def __init__(self, elements, min_size=0, max_size=10):
        self.elements = elements
        self.lo, self.hi = int(min_size), int(max_size)

    def example(self, i, rng):
        if i == 0:
            size = self.lo
        elif i == 1:
            size = self.hi
        else:
            size = rng.randint(self.lo, self.hi)
        return [self.elements.example(rng.randint(2, 1 << 30), rng)
                for _ in range(size)]


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Integers(min_value, max_value)

    @staticmethod
    def floats(min_value, max_value):
        return _Floats(min_value, max_value)

    @staticmethod
    def booleans():
        return _Booleans()

    @staticmethod
    def sampled_from(elements):
        return _SampledFrom(elements)

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        return _Lists(elements, min_size=min_size, max_size=max_size)


_DEFAULT_MAX_EXAMPLES = 20


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    """Records max_examples on the (given-wrapped) test function."""
    def deco(fn):
        fn._propcheck_max_examples = max_examples
        return fn
    return deco


def given(*strats: _Strategy):
    """Replays a fixed example stream through the test body.

    Example i draws each strategy's i-th example (0/1 are the boundary
    values); the RNG is seeded from the test name so runs and reruns see
    the same stream.
    """
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_propcheck_max_examples",
                        _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(zlib.crc32(fn.__name__.encode()))
            for i in range(n):
                vals = [s.example(i, rng) for s in strats]
                try:
                    fn(*args, *vals, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"{fn.__name__} falsified on example {i}: "
                        f"{vals!r}") from e
        # strategy params are supplied here, not by pytest fixtures
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return deco
