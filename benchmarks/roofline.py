"""Roofline analysis per (arch x shape) on the single-pod mesh (§Roofline).

Terms (per device, TPU v5e):
  compute_s    = HLO_FLOPs / 197e12         (bf16 peak per chip)
  memory_s     = HLO_bytes / 819e9          (HBM bandwidth)
  collective_s = collective_bytes / 50e9    (ICI per link)

HLO_FLOPs / bytes / collective_bytes come from the trip-count-aware HLO
parser (hlo_cost.py) over the saved optimized modules — XLA's own
cost_analysis() counts while bodies once and is reported alongside as a
cross-check. MODEL_FLOPS is the analytic 6*N*D / 2*N*D (workload_model).

Output: artifacts/roofline.json + a markdown table on stdout.
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List, Optional

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, "src")

from hlo_cost import analyze_file  # noqa: E402
from workload_model import model_flops  # noqa: E402

from repro.configs import ASSIGNED_ARCHS, SHAPES  # noqa: E402

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # B/s
ICI_BW = 50e9           # B/s per link
N_DEVICES = 256         # single-pod roofline mesh

MOVE_DOWN = {
    "compute": "raise MFU: larger per-device tiles (less DP, more batch "
               "per chip), fuse elementwise chains, drop remat recompute "
               "on cheap ops",
    "memory": "cut HBM traffic: fuse producer->consumer chains (Pallas), "
              "avoid materializing logits/attention intermediates, "
              "bf16-ize fp32 temps",
    "collective": "overlap/shrink collectives: reduce-scatter instead of "
                  "all-reduce+slice, int8-compress DP grads, keep weights "
                  "resident (less FSDP regather), bigger per-device batch",
}


def analyze_cell(arch: str, shape_name: str,
                 art_dir: str = "artifacts/dryrun",
                 mesh: str = "pod16x16") -> Optional[Dict]:
    base = os.path.join(art_dir, f"{arch}__{shape_name}__{mesh}")
    if not os.path.exists(base + ".json"):
        return None
    with open(base + ".json") as f:
        rec = json.load(f)
    if rec.get("status") != "ok":
        return {"arch": arch, "shape": shape_name,
                "status": rec.get("status")}
    out = {"arch": arch, "shape": shape_name, "status": "ok",
           "xla_cost_flops": rec.get("flops_total"),
           "temp_bytes_per_dev": rec.get("temp_size_in_bytes"),
           "arg_bytes_per_dev": rec.get("argument_size_in_bytes")}
    if os.path.exists(base + ".hlo"):
        hc = analyze_file(base + ".hlo")
        flops = hc["flops"]
        bytes_ = hc["hbm_bytes"]
        coll = sum(hc["collective_bytes"].values())
        out.update({
            "hlo_flops": flops, "hlo_bytes": bytes_,
            "hlo_bytes_upper": hc["bytes_upper"],
            "collective_bytes": coll,
            "collective_breakdown": hc["collective_bytes"],
            "compute_s": flops / PEAK_FLOPS,
            "memory_s": bytes_ / HBM_BW,
            "collective_s": coll / ICI_BW,
        })
        terms = {"compute": out["compute_s"], "memory": out["memory_s"],
                 "collective": out["collective_s"]}
        dom = max(terms, key=terms.get)
        bound_s = terms[dom]
        out["dominant"] = dom
        out["step_time_lb_s"] = bound_s
        mf = model_flops(arch, shape_name)
        out["model_flops_per_dev"] = mf["model_flops_global"] / N_DEVICES
        out["useful_ratio"] = out["model_flops_per_dev"] / max(flops, 1.0)
        # roofline fraction: useful model flops per step over what the
        # dominant-term-limited step time could have computed at peak
        out["roofline_frac"] = out["model_flops_per_dev"] / \
            (bound_s * PEAK_FLOPS)
        out["mitigation"] = MOVE_DOWN[dom]
    return out


def full_table(art_dir: str = "artifacts/dryrun") -> List[Dict]:
    rows = []
    for arch in ASSIGNED_ARCHS:
        for shape in SHAPES:
            r = analyze_cell(arch, shape, art_dir)
            if r is not None:
                rows.append(r)
    return rows


def render(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | dominant "
           "| MODEL/HLO | roofline_frac |\n|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"{r['status']} | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_frac']:.3f} |")
    return "\n".join(lines)


def main():
    rows = full_table()
    os.makedirs("artifacts", exist_ok=True)
    with open("artifacts/roofline.json", "w") as f:
        json.dump(rows, f, indent=1)
    print(render(rows))
    ok = [r for r in rows if r.get("status") == "ok"]
    if ok:
        worst = min(ok, key=lambda r: r["roofline_frac"])
        coll_bound = [r for r in ok if r["dominant"] == "collective"]
        print(f"\nworst roofline fraction: {worst['arch']} x "
              f"{worst['shape']} ({worst['roofline_frac']:.3f})")
        print(f"collective-bound cells: "
              f"{[(r['arch'], r['shape']) for r in coll_bound]}")


if __name__ == "__main__":
    main()
