from .fault_tolerance import (FailureInjector, StragglerMonitor,
                              TrainSupervisor, SimulatedFailure)
from .elastic import elastic_restore_plan, reshard_tree
