"""Bitmap sparsity format + the multi-lane sparse decoder functional model.

This is the bit-exact software model of FireFly-T's sparse decoder (paper
Section IV-A1, Eq. 5). The decoder consumes a ``P_Ci``-bit bitmap of spike
activity and extracts up to ``M`` non-zero indices per cycle using carry-
lookahead style propagate/generate logic:

    g_n^m     = i_n  AND  c_n^{m-1}
    o_n^m     = g_n^m AND NOT c_n^m
    c_{n+1}^m = g_n^m OR  c_n^m          (p_n^m == 1 always)

with ``c_n^{-1} = 1`` and ``c_0^m = 0``. Lane ``m`` fires a one-hot at the
position of the (m+1)-th set bit. After a decode cycle the bitmap is updated
to clear the extracted bits; the paper typesets this as
``i_n ∧ c_{n+1}^{M-1}`` — by the lane semantics the bit that must survive is
one with *at least M set bits strictly before it*, i.e. ``i_n ∧ c_n^{M-1}``
(the union of all lane one-hots is exactly ``i_n ∧ ¬c_n^{M-1}``); we
implement that semantics and pin it with property tests
(every set bit is extracted exactly once, in order, M per cycle).

On TPU this bit-serial lane model is the *reference*, not the production
loop: it feeds the cycle-level simulator in ``repro.sim`` that reproduces
the paper's Figs. 12/13, and it pins two hot-path adaptations — the
block-occupancy reduction of the ``spike_matmul`` tile kernel (DESIGN.md
§3) and the cumsum prefix-compaction of the gather-compacted decoded
datapath (``kernels/spike_decode.decode_indices``, DESIGN.md §9), whose
compacted index stream must chunk back into exactly these per-cycle lane
sets (property-pinned in tests/test_spike_decode.py against
:func:`prefix_compact` / :func:`multilane_decode_full`).
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Bit-exact Eq. 5 model
# ---------------------------------------------------------------------------


def multilane_decode_cycle(bits: np.ndarray, m_lanes: int):
    """One decode cycle of the M-lane decoder on a single bitmap.

    Args:
      bits: ``(..., N)`` {0,1} int/bool array — the current bitmap(s).
      m_lanes: number of decoder lanes M.

    Returns:
      (onehots ``(..., M, N)`` bool — per-lane one-hot outputs,
       remaining ``(..., N)`` bool — bitmap with extracted bits cleared).
    """
    bits = np.asarray(bits).astype(bool)
    n = bits.shape[-1]
    # c[m][n] = lane m has fired strictly before position n
    # (vectorized over leading dims; serial over n like the hardware chain).
    c_prev = np.ones(bits.shape[:-1] + (n + 1,), dtype=bool)  # lane -1
    onehots = np.zeros(bits.shape[:-1] + (m_lanes, n), dtype=bool)
    for m in range(m_lanes):
        c = np.zeros_like(c_prev)
        for pos in range(n):
            g = bits[..., pos] & c_prev[..., pos]
            onehots[..., m, pos] = g & ~c[..., pos]
            c[..., pos + 1] = g | c[..., pos]
        c_prev = c
    remaining = bits & c_prev[..., :-1]  # keep bits with >= M set bits before
    return onehots, remaining


def multilane_decode_full(bits: np.ndarray, m_lanes: int):
    """Run decode cycles until the bitmap is exhausted.

    Returns (list of per-cycle index arrays, n_cycles). Indices within a
    cycle are sorted ascending (lane order). A zero bitmap takes 1 cycle
    (load-and-skip), matching the input-tracker behaviour.
    """
    bits = np.asarray(bits).astype(bool).copy()
    assert bits.ndim == 1
    cycles: List[np.ndarray] = []
    if not bits.any():
        return [np.array([], dtype=np.int64)], 1
    while bits.any():
        onehots, bits = multilane_decode_cycle(bits, m_lanes)
        idx = np.nonzero(onehots.any(axis=0))[0]
        cycles.append(idx)
    return cycles, len(cycles)


def prefix_compact(bits: np.ndarray):
    """Numpy reference of the cumsum prefix-compaction (Eq. 5 collapsed
    to ranks): the (r+1)-th set bit of the bitmap lands in compacted slot
    ``r`` — i.e. lane ``r % M`` of decode cycle ``r // M`` for an M-lane
    decoder, whatever M is. Returns (indices ascending, popcount).

    This is the software contract of the decoded datapath's on-device
    compaction (``kernels/spike_decode.decode_indices``): chunking the
    returned indices by M reproduces ``multilane_decode_full``'s
    per-cycle index sets exactly.
    """
    bits = np.asarray(bits).astype(bool)
    rank = np.cumsum(bits) - 1
    idx = np.zeros(bits.shape[-1], dtype=np.int64)
    idx[rank[bits]] = np.nonzero(bits)[0]
    pc = int(bits.sum())
    return idx[:pc], pc


def naive_first_m_indices(bits: np.ndarray, m_lanes: int) -> np.ndarray:
    """Oracle: indices of the first min(M, popcount) set bits."""
    idx = np.nonzero(np.asarray(bits).astype(bool))[0]
    return idx[:m_lanes]


def decode_cycles_for_word(popcount: int, m_lanes: int) -> int:
    """Cycles to decode one bitmap word given the input tracker policy.

    The tracker is initialized with the word's popcount and decremented by M
    per cycle; a new word may load once the tracker is <= M, so a word
    occupies ``max(1, ceil(popcount / M))`` decoder cycles.
    """
    return max(1, -(-popcount // m_lanes))


# ---------------------------------------------------------------------------
# Bitmap tensor format (software CSR/bitmap hybrid used by the simulator)
# ---------------------------------------------------------------------------


def bitmap_encode(spikes: np.ndarray, word: int = 32):
    """Encode a binary activation tensor into (words, popcounts).

    ``spikes``: (..., C) with C % word == 0. Returns ``words`` (..., C//word)
    uint64 bit words and ``pc`` per-word popcounts (int32).
    """
    spikes = np.asarray(spikes)
    c = spikes.shape[-1]
    if c % word:
        raise ValueError(f"channel dim {c} not a multiple of {word}")
    bits = (spikes != 0).reshape(*spikes.shape[:-1], c // word, word)
    weights = (1 << np.arange(word, dtype=np.uint64))
    words = (bits.astype(np.uint64) * weights).sum(axis=-1)
    pc = bits.sum(axis=-1).astype(np.int32)
    return words, pc


def bitmap_decode(words: np.ndarray, c: int, word: int = 32) -> np.ndarray:
    """Inverse of :func:`bitmap_encode` -> float32 {0,1} tensor (..., C)."""
    words = np.asarray(words, dtype=np.uint64)
    bits = (words[..., None] >> np.arange(word, dtype=np.uint64)) & np.uint64(1)
    return bits.reshape(*words.shape[:-1], c).astype(np.float32)


def block_occupancy(spikes: np.ndarray, block: int) -> np.ndarray:
    """Per-block any-nonzero mask along the last dim — the MXU-granularity
    adaptation of the sparse decoder (see spike_matmul kernel)."""
    c = spikes.shape[-1]
    pad = (-c) % block
    if pad:
        spikes = np.concatenate(
            [spikes, np.zeros((*spikes.shape[:-1], pad), spikes.dtype)], -1)
    blocks = spikes.reshape(*spikes.shape[:-1], -1, block)
    return (blocks != 0).any(axis=-1)
