"""Fused layer-program step (kernels/fused_layer.py, engine.layer_step).

Pins, in order of the stack:
  * the whole-layer kernel (SSA bundle + output projection + spiking
    MLP as one Pallas grid) is bitwise equal to the *jitted* sequential
    oracle (``reference_layer``) for both epilogue families, across
    ``sparse in {tile, decoded}`` and ``overlap in {fused, pipeline}``,
    including non-divisible L, dark time slabs, all-zero inputs and
    int8-quantized weights. The oracle must be jitted: the kernel body
    is always compiled and compiled dots FMA-contract, so the eager
    reference is NOT the contract (see tests/test_spike_decode.py);
  * the ``(H, 8, n_l_blocks)`` occupancy map is exact and identical
    between the fused and pipeline grids;
  * ``resolve_layer_plan`` folds overlap + sparse dispatch into one
    static plan (tracer -> off, below min_flops -> off, explicit
    honored) and ineligible layers (gated MLP, biased linears) take the
    sequential fallback instead of the kernel;
  * whole-model logits AND grads are bitwise identical across
    ``overlap in {off, fused, pipeline}`` x ``sparse in {tile,
    decoded}`` on the spikingformer configs — also under jit and with
    int8-quantized weights (eligible layers share one custom-VJP step,
    so all modes run one gradient program: ``engine._fused_layer``);
  * ``fused_step_metrics``' 3-D occupancy-map path (layer event
    schedule, binary-hidden fraction) and the ``sim/balance_sim
    .binary_block_schedule`` numpy twin;
  * the bench-regression gate fails loud on stale baseline key families
    and enforces the layer hidden-fraction floor even at
    ``--update-baselines`` time (negative-tested).

Bit-exactness strategy matches tests/test_fused_ssa.py: dyadic-grid
weights make fp32 accumulation order-exact, so equality is to the bit.
"""
import json
import math
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import dual_engine as de
from repro.core import engine as E
from repro.core.spiking import SpikingConfig, lif_scan
from repro.kernels import fused_layer as FL
from repro.models import registry
from repro.sim.balance_sim import binary_block_schedule

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))


def _dyadic(key, shape):
    return (jax.random.randint(key, shape, -128, 128)
            .astype(jnp.float32)) * (2.0 ** -8)


def _bn_rows(key, n):
    k1, k2 = jax.random.split(key)
    return jnp.stack([_dyadic(k1, (n,)) * 0.25,
                      jnp.abs(_dyadic(k2, (n,))) + 0.5,
                      jnp.ones((n,)) * 1.25,
                      jnp.full((n,), 0.0625)])


def _layer_ops(key, t, b, l, d, heads, hd, ff, *, family, quant=False):
    """Raw kernel operands (the layout ``engine.layer_step`` builds),
    with a dark (t=0, b=0) slab and an all-zero row."""
    q_dim = heads * hd
    ks = jax.random.split(key, 8)
    x = (jax.random.uniform(ks[0], (t, b, l, d)) < 0.3
         ).astype(jnp.float32)
    x = x.at[:, :, min(2, l - 1)].set(0.0)
    x = x.at[0, 0].set(0.0)
    if quant:
        def qw(k, shape, n):
            return (jax.random.randint(k, shape, -128, 128)
                    .astype(jnp.int8).astype(jnp.float32),
                    jnp.abs(_dyadic(jax.random.fold_in(k, 1), (n,))) + 0.5)
        w3, sc3 = qw(ks[1], (3, d, q_dim), q_dim)
        sc3 = jnp.broadcast_to(sc3, (3, q_dim))
        wo, sco = qw(ks[2], (q_dim, d), d)
        w1, sc1 = qw(ks[3], (d, ff), ff)
        w2, sc2 = qw(ks[4], (ff, d), d)
        scales = (sc3, sco, sc1, sc2)
    else:
        w3 = _dyadic(ks[1], (3, d, q_dim))
        wo = _dyadic(ks[2], (q_dim, d))
        w1 = _dyadic(ks[3], (d, ff))
        w2 = _dyadic(ks[4], (ff, d))
        scales = None
    if family == "bn":
        auxp = jnp.stack([_bn_rows(k, q_dim)
                          for k in jax.random.split(ks[5], 3)])
        auxo = _bn_rows(ks[6], d)
        aux1 = _bn_rows(jax.random.fold_in(ks[6], 1), ff)
        aux2 = _bn_rows(jax.random.fold_in(ks[6], 2), d)
        s = lif_scan(x, SpikingConfig(time_steps=t))[0]
    else:
        half = hd // 2
        freqs = 10000.0 ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
        ang = jnp.arange(l, dtype=jnp.float32)[:, None] * freqs
        auxp = jnp.stack([jnp.cos(ang), jnp.sin(ang)])
        auxo = jnp.ones((1, d), jnp.float32)
        aux1 = aux2 = None
        x32 = x.astype(jnp.float32)
        s = (x32 * jax.lax.rsqrt(
            jnp.mean(x32 * x32, axis=-1, keepdims=True) + 1e-6)
        ).astype(x.dtype)
    # the engine zero-pads d_ff to a heads multiple before the kernel
    # boundary (exact — identity BN rows, zero up/down pad)
    sc1 = scales[2] if quant else jnp.ones((ff,), jnp.float32)
    w1, w2, sc1, aux1 = E._pad_ff(w1, w2, sc1, aux1, heads)
    if quant:
        scales = (scales[0], scales[1], sc1, scales[3])
    return (x, s, w3, wo, w1, w2, scales, auxp, auxo, aux1, aux2, 0.3)


# (t, b, l, d, heads, hd, ff): non-divisible L vs l_block=8, ff not a
# heads multiple (exercises the exact zero-pad)
SHAPE = (2, 2, 13, 16, 2, 8, 21)
L_BLOCK, C_BLOCK = 8, 8


def _run(args, family, sparse, pipeline, causal=None):
    causal = (family == "rope") if causal is None else causal
    kw = dict(family=family, num_heads=SHAPE[4], head_dim=SHAPE[5],
              scale=1.0 / math.sqrt(SHAPE[5]), causal=causal)
    out, cnt = FL.fused_layer(*args, sparse=sparse, pipeline=pipeline,
                              l_block=L_BLOCK, c_block=C_BLOCK, **kw)
    scfg = SpikingConfig(time_steps=SHAPE[0])
    ref = jax.jit(lambda *a: FL.reference_layer(*a, scfg, **kw))(*args)
    return out, cnt, ref


@pytest.mark.parametrize("family,sparse", [("bn", "tile"),
                                           ("bn", "decoded"),
                                           ("rope", "tile")])
@pytest.mark.parametrize("pipeline", [False, True])
def test_layer_kernel_matches_jitted_oracle_bitwise(family, sparse,
                                                    pipeline):
    t, b, l, d, heads, hd, ff = SHAPE
    args = _layer_ops(jax.random.PRNGKey(11), t, b, l, d, heads, hd, ff,
                      family=family)
    out, cnt, ref = _run(args, family, sparse, pipeline)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    cnt = np.asarray(cnt)
    assert cnt.shape == (heads, 8, -(-l // L_BLOCK))
    if family == "bn" and sparse == "tile":
        # dark (t=0, b=0) slab skipped in every projection phase/block
        assert (cnt[:, :3].sum(axis=-1) <= 3 * (t * b - 1)).all()


def test_layer_counts_identical_fused_vs_pipeline():
    t, b, l, d, heads, hd, ff = SHAPE
    args = _layer_ops(jax.random.PRNGKey(5), t, b, l, d, heads, hd, ff,
                      family="bn")
    _, c_f, _ = _run(args, "bn", "tile", False)
    _, c_p, _ = _run(args, "bn", "tile", True)
    np.testing.assert_array_equal(np.asarray(c_f), np.asarray(c_p))


def test_layer_kernel_int8_weights_bitwise():
    t, b, l, d, heads, hd, ff = SHAPE
    args = _layer_ops(jax.random.PRNGKey(9), t, b, l, d, heads, hd, ff,
                      family="bn", quant=True)
    for sparse in ("tile", "decoded"):
        out, _, ref = _run(args, "bn", sparse, True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_layer_kernel_all_zero_timestep():
    t, b, l, d, heads, hd, ff = SHAPE
    args = _layer_ops(jax.random.PRNGKey(3), t, b, l, d, heads, hd, ff,
                      family="bn")
    args = (jnp.zeros_like(args[0]), jnp.zeros_like(args[1])) + args[2:]
    out, cnt, ref = _run(args, "bn", "tile", False)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    # every projection slab dark -> zero executed projection sub-blocks
    np.testing.assert_array_equal(np.asarray(cnt)[:, :3], 0)


def test_binary_block_schedule_twin_matches_kernel_counts():
    t, b, l, d, heads, hd, ff = SHAPE
    args = _layer_ops(jax.random.PRNGKey(13), t, b, l, d, heads, hd, ff,
                      family="bn")
    _, cnt, _ = _run(args, "bn", "tile", False)
    # the twin predicts the binary phases from the projection spikes the
    # kernel emits; recompute them under jit (compiled dots contract)
    scfg = SpikingConfig(time_steps=t)

    @jax.jit
    def kv(s, w3, auxp):
        out = []
        for i in (1, 2):
            cur = jnp.dot(s, w3[i], preferred_element_type=jnp.float32)
            y = cur.astype(s.dtype).astype(jnp.float32)
            y = (y - auxp[i, 0]) * jax.lax.rsqrt(auxp[i, 1] + 1e-5)
            y = (y * auxp[i, 2] + auxp[i, 3]).astype(s.dtype)
            out.append(lif_scan(y, scfg)[0])
        return tuple(out)

    ksp, vsp = kv(args[1], args[2], args[7])
    pred = binary_block_schedule(np.asarray(ksp), np.asarray(vsp), heads,
                                 L_BLOCK, 0.3)
    np.testing.assert_array_equal(pred, np.asarray(cnt)[:, 3:5, :])


def test_binary_block_schedule_predicate_edges():
    k = np.zeros((2, 1, 8, 4))
    v = np.ones((2, 1, 8, 4))
    # all-dark keys: nothing live under binarize with delta > 0 ...
    out = binary_block_schedule(k, v, 1, 4, delta=0.3)
    np.testing.assert_array_equal(out, 0)
    # ... everything qkt-live when delta <= 0 or scores stay analog
    # (zeros binarize to ones at delta <= 0, so the block must execute)
    for kw in (dict(delta=0.0), dict(delta=0.3, binarize=False)):
        out = binary_block_schedule(k, v, 1, 4, **kw)
        np.testing.assert_array_equal(out[:, 0], 2)  # t*b per block
        np.testing.assert_array_equal(out[:, 1], 2)  # live v rides along
        # ... but a dark value block still kills the context phase
        out = binary_block_schedule(k, np.zeros_like(v), 1, 4, **kw)
        np.testing.assert_array_equal(out[:, 1], 0)


# ---------------------------------------------------------------------------
# dispatch rules + sequential fallback
# ---------------------------------------------------------------------------


BIG = 1 << 40


def test_resolve_layer_plan_rules():
    x = jnp.ones((2, 2, 8, 16))
    assert E.resolve_layer_plan(None, x, BIG) == ("off", "tile")
    eng = E.EngineConfig(overlap="pipeline", sparse="decoded")
    assert E.resolve_layer_plan(eng, x, 0) == ("pipeline", "decoded")
    auto = E.EngineConfig(overlap="auto")
    assert E.resolve_layer_plan(auto, x, BIG).overlap == "fused"
    assert E.resolve_layer_plan(auto, x, 10).overlap == "off"

    seen = []

    @jax.jit
    def f(u):
        seen.append((E.resolve_layer_plan(auto, u, BIG).overlap,
                     E.resolve_layer_plan(eng, u, 0).overlap))
        return u

    f(x)
    assert seen == [("off", "pipeline")]  # tracer -> off; explicit honored


def test_ineligible_layer_takes_sequential_fallback(monkeypatch):
    """A layer the fused program has no mapping for (gated MLP, biased
    linear) must run the sequential composition — pinned by making the
    kernel explode and checking only the eligible layer reaches it."""
    from repro.models import nn, transformer

    def boom(*a, **k):
        raise AssertionError("fused kernel reached for ineligible layer")

    monkeypatch.setattr(FL, "fused_layer", boom)
    cfg = get_config("spikingformer-lm", smoke=True)
    p = jax.tree_util.tree_map(
        lambda a: a[0], registry.init(cfg, jax.random.PRNGKey(0))["layers"])
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (cfg.spiking.time_steps, 1, 8, cfg.d_model))
    pos = jnp.arange(8)
    eng = cfg.engine.replace(overlap="fused")
    with pytest.raises(AssertionError, match="ineligible"):
        E.layer_step_causal(p, cfg, x, pos, engine=eng)
    gated = dict(p, mlp=dict(p["mlp"], gate=nn.linear_init(
        jax.random.PRNGKey(2), cfg.d_model, cfg.d_ff)))
    out = E.layer_step_causal(gated, cfg, x, pos, engine=eng)
    assert out.shape == x.shape
    # ... and the fallback matches the model's own pre-engine layer
    # composition: overlap='off' without the kernel still works
    off = E.layer_step_causal(p, cfg, x, pos,
                              engine=cfg.engine.replace(overlap="off"))
    assert off.shape == x.shape


# ---------------------------------------------------------------------------
# whole-model parity: logits + grads across all modes
# ---------------------------------------------------------------------------


SPIKING_ARCHS = ["spikingformer-4-256", "spikingformer-8-512",
                 "spikingformer-lm"]
MODES = [("off", "tile"), ("fused", "tile"), ("fused", "decoded"),
         ("pipeline", "tile"), ("pipeline", "decoded")]


def _model_setup(arch, quant=None):
    cfg = get_config(arch, smoke=True)
    params = jax.tree_util.tree_map(
        lambda a: jnp.round(a * 256) / 256
        if jnp.issubdtype(a.dtype, jnp.floating) else a,
        registry.init(cfg, jax.random.PRNGKey(0)))
    if quant:
        from repro.quant import quantize_tree
        params = quantize_tree(params, quant, dyadic=True)
    if cfg.family == "dense":
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                              (2, 16), 0, cfg.vocab_size)}
    else:
        batch = {"images": jax.random.uniform(
            jax.random.PRNGKey(1),
            (2, cfg.vision.img_size, cfg.vision.img_size,
             cfg.vision.in_channels))}
    return cfg, params, batch


def _mode_logits(cfg, params, batch, modes):
    outs = []
    for ov, sp in modes:
        with E.use_engine(cfg.engine.replace(overlap=ov, sparse=sp)):
            logits, _ = registry.forward(params, cfg, batch)
        outs.append(np.asarray(logits))
    return outs


@pytest.mark.parametrize("arch", SPIKING_ARCHS)
def test_model_logits_bitwise_all_modes(arch):
    cfg, params, batch = _model_setup(arch)
    modes = MODES if cfg.family != "dense" else \
        [m for m in MODES if m[1] == "tile"]  # decoded is spike-driven
    outs = _mode_logits(cfg, params, batch, modes)
    for got in outs[1:]:
        np.testing.assert_array_equal(outs[0], got)


@pytest.mark.parametrize("arch,sparse", [("spikingformer-4-256", "decoded"),
                                         ("spikingformer-lm", "tile")])
def test_model_grads_bitwise_all_modes(arch, sparse):
    cfg, params, batch = _model_setup(arch)

    def loss(p, eng):
        with E.use_engine(eng):
            logits, _ = registry.forward(p, cfg, batch)
        return jnp.sum(logits ** 2) * 1e-3

    grads = [jax.grad(loss)(params,
                            cfg.engine.replace(overlap=ov, sparse=sparse))
             for ov in ("off", "fused", "pipeline")]
    for g in grads[1:]:
        for a, b in zip(jax.tree_util.tree_leaves(grads[0]),
                        jax.tree_util.tree_leaves(g)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_model_int8_logits_and_grads_bitwise():
    cfg, params, batch = _model_setup("spikingformer-4-256", quant="int8")
    outs = _mode_logits(cfg, params, batch,
                        [("off", "tile"), ("fused", "decoded"),
                         ("pipeline", "tile")])
    for got in outs[1:]:
        np.testing.assert_array_equal(outs[0], got)

    def loss(p, eng):
        with E.use_engine(eng):
            logits, _ = registry.forward(p, cfg, batch)
        return jnp.sum(logits ** 2) * 1e-3

    # int8 code leaves take float0 grads (allow_int); the fp leaves —
    # scales, norms, head — must still agree bitwise across modes
    ga = jax.grad(loss, allow_int=True)(params,
                                        cfg.engine.replace(overlap="off"))
    gb = jax.grad(loss, allow_int=True)(
        params, cfg.engine.replace(overlap="pipeline"))
    for a, b in zip(jax.tree_util.tree_leaves(ga),
                    jax.tree_util.tree_leaves(gb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_model_logits_bitwise_under_jit():
    """Explicit overlap='pipeline' is honored under jit (the layer sits
    inside the block scan, so the plan resolves on a tracer — explicit
    modes must survive that)."""
    cfg, params, batch = _model_setup("spikingformer-lm")
    outs = {}
    for ov in ("off", "pipeline"):
        eng = cfg.engine.replace(overlap=ov)

        @jax.jit
        def f(p):
            with E.use_engine(eng):
                return registry.forward(p, cfg, batch)[0]

        outs[ov] = np.asarray(f(params))
    np.testing.assert_array_equal(outs["off"], outs["pipeline"])


# ---------------------------------------------------------------------------
# occupancy-map metrics (the 3-D fused_step_metrics path)
# ---------------------------------------------------------------------------


def _layer_metrics(counts, **over):
    kw = dict(seq=16, k_dim=16, head_dim=8, t_steps=2, batch=2,
              d_model=16, d_ff=32, l_block=8, sparse="tile",
              c_block=None, pipeline=False)
    kw.update(over)
    return de.fused_step_metrics(counts, **kw)


def test_fused_step_metrics_dispatches_on_rank():
    m2 = de.fused_step_metrics([[4, 4, 4, 8], [4, 4, 4, 8]],
                               seq=16, k_dim=16, head_dim=8, t_steps=2,
                               batch=2)
    assert "proj_skip_fraction" in m2 and "executed_down" not in m2
    m3 = _layer_metrics([[[4]] * 8, [[4]] * 8])
    assert "executed_down" in m3 and m3["l_blocks"] == 1


def test_layer_metrics_counts_and_bounds():
    full = 2 * 2  # t * b possible per (head, phase, block); heads=2, nlb=2
    counts = np.full((2, 8, 2), full, np.int64)
    m = _layer_metrics(counts)
    assert m["executed_steps"] == counts.sum()
    assert m["possible_steps"] == 8 * 2 * 2 * full
    assert m["step_reduction"] == 0.0
    assert 0.0 <= m["hidden_fraction"] <= 1.0
    assert m["sparse_util"] <= 1.0 and m["binary_util"] <= 1.0
    # decoded projections: q/k/v possible scale by the c_block chunks
    md = _layer_metrics(counts, sparse="decoded", c_block=8)
    assert md["possible_steps"] > m["possible_steps"]
    # half the counts -> half the executed steps
    mh = _layer_metrics(counts // 2)
    assert mh["executed_steps"] == m["executed_steps"] // 2


def test_layer_metrics_degenerate_schedules():
    # binary-only work: nothing to hide behind -> hidden fraction 0
    counts = np.zeros((1, 8, 1), np.int64)
    counts[:, 3:5] = 4
    m = _layer_metrics(counts)
    assert m["hidden_fraction"] == 0.0
    # sparse-only work: no binary busy time -> defined as 0
    counts = np.zeros((1, 8, 1), np.int64)
    counts[:, :3] = 4
    assert _layer_metrics(counts)["hidden_fraction"] == 0.0


def test_layer_event_schedule_dependencies():
    macs = {ph: [10.0] for ph in de.LAYER_PHASE_NAMES}
    se, be = de.layer_event_schedule(macs, heads=1)
    ends = {n: e for n, _, e in se}
    starts = {n: s for n, s, _ in be}
    # binary qkt waits for the sparse k phase; qktv for v
    assert starts["qkt0@0"] >= ends["k0@0"]
    assert starts["qktv0@0"] >= ends["v0@0"]
    # sparse wo stalls on the binary context (qktv) of its head
    wo_start = [s for n, s, _ in se if n == "wo0@0"][0]
    qktv_end = [e for n, _, e in be if n == "qktv0@0"][0]
    assert wo_start >= qktv_end
    # pipeline chaining keeps total busy time, never stretches it
    se2, be2 = de.layer_event_schedule(macs, heads=1, iters=2)
    busy = sum(e - s for _, s, e in se)
    busy2 = sum(e - s for _, s, e in se2)
    assert abs(busy - busy2) < 1e-6


# ---------------------------------------------------------------------------
# bench-regression gate: stale families + floors (negative tests)
# ---------------------------------------------------------------------------


def _gate_dirs(tmp_path):
    import check_regression as cr
    art = tmp_path / "artifacts"
    base = tmp_path / "baselines"
    art.mkdir(), base.mkdir()
    here = os.path.join(os.path.dirname(__file__), "..")
    for name in cr.SPECS:
        with open(os.path.join(here, "benchmarks", "baselines", name)) as f:
            pairs = json.load(f)
        (base / name).write_text(json.dumps(pairs))
    for name in cr.SPECS:
        with open(os.path.join(here, "artifacts", name)) as f:
            (art / name).write_text(f.read())
    return cr, str(art), str(base)


def test_gate_fails_loud_on_stale_baseline_family(tmp_path, capsys):
    cr, art, base = _gate_dirs(tmp_path)
    assert cr.check(art, base, update=False) == 0
    bp = os.path.join(base, "dual_engine_bench.json")
    with open(bp) as f:
        stale = json.load(f)
    stale["ghost_bench/some/metric"] = 1.0
    with open(bp, "w") as f:
        json.dump(stale, f)
    assert cr.check(art, base, update=False) == 1
    out = capsys.readouterr().out
    assert "stale baseline family 'ghost_bench'" in out


def test_gate_floor_holds_even_on_update(tmp_path, capsys):
    cr, art, base = _gate_dirs(tmp_path)
    ap = os.path.join(art, "dual_engine_bench.json")
    with open(ap) as f:
        blob = json.load(f)
    for r in blob["layer_rows"]:
        if r["config"] == "spikingformer-lm" and r["overlap"] != "off":
            r["hidden_fraction"] = 0.10          # below the 0.3971 floor
    with open(ap, "w") as f:
        json.dump(blob, f)
    assert cr.check(art, base, update=False) == 1
    assert "strictly above the floor" in capsys.readouterr().out
    # --update-baselines must refuse to ratify the below-floor artifact
    assert cr.check(art, base, update=True) == 1
    with open(os.path.join(base, "dual_engine_bench.json")) as f:
        kept = json.load(f)
    key = "layer/spikingformer-lm/fused/tile/hidden_fraction"
    assert kept[key] > 0.3971                    # old baseline untouched
