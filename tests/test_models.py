"""Model-family correctness: forward/decode consistency, spiking mode,
MoE invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container has no hypothesis; use fixed-seed shim
    from _propcheck import given, settings, strategies as st

from repro.configs import get_config
from repro.configs.base import ModelConfig, MoEConfig
from repro.core.spiking import SpikingConfig
from repro.models import moe as moe_mod, registry


def _decode_vs_forward(arch, n=10, max_len=24):
    cfg = get_config(arch, smoke=True)
    params = registry.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, n)), jnp.int32)
    batch = {"tokens": toks}
    if cfg.family == "encdec":
        batch["audio_embeds"] = jnp.asarray(
            rng.normal(0, 0.1, (2, cfg.encoder_seq,
                                cfg.d_model)).astype(np.float32))
    logits, _ = registry.forward(params, cfg, batch)
    cache = registry.init_cache(cfg, 2, max_len, batch=batch, params=params)
    step = jax.jit(lambda c, t, p: registry.decode_step(params, cfg, c, t, p))
    outs = []
    for i in range(n):
        lg, cache = step(cache, toks[:, i:i + 1], jnp.asarray(i, jnp.int32))
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits),
                               atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("arch", ["nemotron-4-15b", "gemma3-12b",
                                  "h2o-danube-3-4b", "granite-20b",
                                  "deepseek-moe-16b", "rwkv6-3b",
                                  "hymba-1.5b", "whisper-small"])
def test_decode_matches_forward(arch):
    """Token-by-token decode == full-sequence forward (all cache kinds:
    full, rolling-window, local+global, MoE, recurrent states)."""
    _decode_vs_forward(arch)


def test_vlm_decode_continues_prefill():
    cfg = get_config("llava-next-mistral-7b", smoke=True)
    params = registry.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, 512, (2, 6)), jnp.int32),
             "patch_embeds": jnp.asarray(
                 rng.normal(0, 0.1, (2, cfg.frontend.num_embeds,
                                     cfg.frontend.embed_dim)).astype(
                     np.float32))}
    logits, _ = registry.forward(params, cfg, batch)
    assert logits.shape[1] == 6 + cfg.frontend.num_embeds
    cache = registry.init_cache(cfg, 2, 32)
    lg, cache = registry.decode_step(params, cfg, cache,
                                     batch["tokens"][:, :1],
                                     jnp.asarray(0, jnp.int32))
    assert np.isfinite(np.asarray(lg)).all()


def test_spiking_dense_lm_binary_activations():
    cfg = get_config("h2o-danube-3-4b", smoke=True).replace(
        spiking=SpikingConfig(time_steps=2))
    params = registry.init(cfg, jax.random.PRNGKey(0))
    toks = jnp.ones((2, 8), jnp.int32)
    logits, _ = registry.forward(params, cfg, {"tokens": toks}, train=True)
    assert np.isfinite(np.asarray(logits)).all()
    g = jax.grad(lambda p: registry.forward(
        p, cfg, {"tokens": toks}, train=True)[0].sum())(params)
    total = sum(float(jnp.abs(l).sum())
                for l in jax.tree_util.tree_leaves(g))
    assert np.isfinite(total) and total > 0


# ---------------------------------------------------------------------------
# MoE invariants
# ---------------------------------------------------------------------------

_MCFG = ModelConfig(
    name="m", family="moe", num_layers=2, d_model=32, num_heads=4,
    num_kv_heads=2, head_dim=8, d_ff=64, vocab_size=64, dtype="float32",
    remat=False, moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=16))


@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(st.integers(4, 64), st.integers(1, 4))
def test_router_topk_invariants(t, k):
    m = MoEConfig(num_experts=8, top_k=k, d_ff_expert=16)
    x = jax.random.normal(jax.random.PRNGKey(t), (t, 16))
    w_router = jax.random.normal(jax.random.PRNGKey(k), (16, 8))
    w, idx, aux_lb, aux_z = moe_mod.router_topk(x, w_router, m)
    assert w.shape == (t, k) and idx.shape == (t, k)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-5)
    assert (np.asarray(idx) >= 0).all() and (np.asarray(idx) < 8).all()
    # per row, indices distinct
    for row in np.asarray(idx):
        assert len(set(row.tolist())) == k
    assert float(aux_lb) >= 1.0 - 1e-3  # >= 1 by Cauchy-Schwarz, = 1 uniform


def test_moe_dispatch_matches_dense_at_high_capacity():
    """With capacity >= tokens, sort-based dispatch == explicit per-token
    expert mixture."""
    m = MoEConfig(num_experts=4, top_k=2, d_ff_expert=8,
                  capacity_factor=8.0)
    t, d = 12, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (t, d))
    up = jax.random.normal(ks[1], (4, d, 8)) * 0.3
    gate = jax.random.normal(ks[2], (4, d, 8)) * 0.3
    down = jax.random.normal(ks[3], (4, 8, d)) * 0.3
    w = jax.nn.softmax(jax.random.normal(ks[4], (t, 4)), -1)
    wk, idx = jax.lax.top_k(w, 2)
    wk = wk / wk.sum(-1, keepdims=True)
    got = moe_mod._dispatch_local(x, wk, idx, up, gate, down, m, "silu",
                                  4, 0)
    want = np.zeros((t, d), np.float32)
    for i in range(t):
        for j in range(2):
            e = int(idx[i, j])
            h = jax.nn.silu(x[i] @ gate[e]) * (x[i] @ up[e])
            want[i] += float(wk[i, j]) * np.asarray(h @ down[e])
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-4, rtol=1e-4)


def test_moe_capacity_drops_overflow():
    m = MoEConfig(num_experts=2, top_k=1, d_ff_expert=4,
                  capacity_factor=0.5)
    t, d = 8, 8
    x = jnp.ones((t, d))
    up = jnp.ones((2, d, 4)) * 0.1
    gate = jnp.ones((2, d, 4)) * 0.1
    down = jnp.ones((2, 4, d)) * 0.1
    w = jnp.ones((t, 1))
    idx = jnp.zeros((t, 1), jnp.int32)  # everyone wants expert 0
    got = moe_mod._dispatch_local(x, w, idx, up, gate, down, m, "silu", 2, 0)
    served = (np.abs(np.asarray(got)).sum(-1) > 0).sum()
    assert served == 2  # capacity = ceil(8*1/2*0.5) = 2
