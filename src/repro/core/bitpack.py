"""Bit-packing utilities for binary spike tensors.

FireFly-T's binary engine operates on 1-bit operands; on TPU the analogous
storage optimization is packing spikes into ``uint32`` lanes so that a
``P_Bk``-wide AND-PopCount becomes ``population_count(a & b)`` summed over
words. These helpers implement the packing and a popcount-based binary
matmul used by the ``popcount_attention`` kernel's reference path and by the
property tests that pin the MXU kernel to the bit-exact semantics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

WORD = 32
_WEIGHTS = (jnp.uint32(1) << jnp.arange(WORD, dtype=jnp.uint32))


def pad_to_multiple(x: jax.Array, axis: int, mult: int) -> jax.Array:
    """Zero-pad ``axis`` up to the next multiple of ``mult`` (no-op when
    it already divides). Shared by the packing below and every Pallas
    kernel's non-divisible-shape handling: zero spikes are AND-PopCount
    neutral and contribute exact fp32 zeros to any accumulation."""
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def pack_bits(x: jax.Array) -> jax.Array:
    """Pack binary values along the last axis into uint32 words.

    ``(..., n)`` -> ``(..., ceil(n / 32))`` uint32. Bit ``j`` of word ``w``
    is element ``w * 32 + j`` (little-endian bits). A last dim that does
    not fill the final word is zero-padded: zero bits are AND-PopCount
    neutral, so every popcount consumer (``popcount_matmul``, the
    ``popcount_attention`` kernel, the packed decode KV cache) stays
    bit-exact on head dims like 16 or 48.
    """
    n = x.shape[-1]
    x = pad_to_multiple(x, -1, WORD)
    words = x.shape[-1] // WORD
    bits = (x != 0).astype(jnp.uint32).reshape(*x.shape[:-1], words, WORD)
    return (bits * _WEIGHTS).sum(axis=-1, dtype=jnp.uint32)


def unpack_bits(p: jax.Array, n: int, dtype=jnp.float32) -> jax.Array:
    """Inverse of :func:`pack_bits`: ``(..., ceil(n/32))`` uint32 ->
    ``(..., n)`` (padding bits of the final word are dropped)."""
    if -(-n // WORD) != p.shape[-1]:
        raise ValueError(f"n={n} inconsistent with packed shape {p.shape}")
    bits = (p[..., None] >> jnp.arange(WORD, dtype=jnp.uint32)) & jnp.uint32(1)
    full = bits.reshape(*p.shape[:-1], p.shape[-1] * WORD)
    return full[..., :n].astype(dtype)


def popcount_matmul(a_packed: jax.Array, b_packed: jax.Array) -> jax.Array:
    """Binary matmul via AND + population count on packed operands.

    ``a_packed``: (..., M, W) uint32, ``b_packed``: (..., N, W) uint32
    (both packed along the contraction dim). Returns (..., M, N) int32
    counts — bit-exact equal to ``a @ b.T`` on the unpacked {0,1} arrays.
    """
    anded = a_packed[..., :, None, :] & b_packed[..., None, :, :]
    return jax.lax.population_count(anded).sum(axis=-1).astype(jnp.int32)


def popcount(x: jax.Array) -> jax.Array:
    """Total number of set bits of a packed uint32 array."""
    return jax.lax.population_count(x).sum(dtype=jnp.int32)
