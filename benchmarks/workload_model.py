"""Analytic MODEL_FLOPS per (arch x shape): 6*N*D (dense train) /
6*N_active*D (MoE train) / 2*N*D (inference), plus parameter censuses.

Used by the roofline to compute the "useful compute" ratio
MODEL_FLOPS / HLO_FLOPs (catches remat/redundancy waste).
"""
from __future__ import annotations

import sys
from typing import Dict

import numpy as np

sys.path.insert(0, "src")

import jax  # noqa: E402

from repro.configs import SHAPES, get_config  # noqa: E402
from repro.launch.steps import abstract_params  # noqa: E402


def _path_str(path) -> str:
    parts = []
    for p in path:
        parts.append(str(getattr(p, "key", getattr(p, "idx", p))))
    return "/".join(parts)


def param_census(arch: str) -> Dict[str, float]:
    """Total / embedding / expert / active parameter counts."""
    cfg = get_config(arch)
    tree = abstract_params(cfg)
    total = embed = expert = 0.0
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in flat:
        n = float(np.prod(leaf.shape))
        p = _path_str(path)
        total += n
        if "embed/table" in p or "lm_head" in p or "pos_embed" in p:
            embed += n
        if "moe/up" in p or "moe/gate" in p or "moe/down" in p:
            expert += n
    active = total - expert
    if cfg.moe is not None:
        active += expert * cfg.moe.top_k / cfg.moe.num_experts
    return {"total": total, "embed": embed, "expert": expert,
            "active": active, "active_nonembed": active - embed}


def model_flops(arch: str, shape_name: str) -> Dict[str, float]:
    """Global analytic FLOPs for one step of this cell.

    train:   6 * N_active * D   (fwd 2ND + bwd 4ND; N excludes the input
             embedding gather but includes the lm_head matmul)
    prefill: 2 * N_active * D
    decode:  2 * N_active * B   (one token per sequence)
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    c = param_census(arch)
    # lm_head participates in matmul flops; input embedding does not
    n_eff = c["active"] - c["embed"] / 2.0
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        flops = 6.0 * n_eff * tokens
    elif shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        flops = 2.0 * n_eff * tokens
    else:
        tokens = shape.global_batch
        flops = 2.0 * n_eff * tokens
        # decode attention: reads the KV cache, flops 2*L*d per head pair
        if cfg.family in ("dense", "moe", "vlm", "encdec", "hybrid"):
            if cfg.attn_type == "swa":
                l_eff = min(shape.seq_len, cfg.window) * cfg.num_layers
            elif cfg.attn_type == "local_global":
                g = cfg.num_layers // cfg.global_every
                l_eff = (g * (cfg.global_every - 1) *
                         min(shape.seq_len, cfg.window) +
                         g * shape.seq_len)
            else:
                l_eff = shape.seq_len * cfg.num_layers
            flops += (shape.global_batch * 2 *
                      2 * l_eff * cfg.num_heads * cfg.head_dim)
    return {"model_flops_global": flops, "tokens": float(tokens), **c}
