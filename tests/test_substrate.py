"""Optimizers, schedules, gradient compression, checkpointing, data."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container has no hypothesis; use fixed-seed shim
    from _propcheck import given, settings, strategies as st

from repro.checkpoint import CheckpointManager, restore_tree, save_tree
from repro.data import DataConfig, make_pipeline
from repro.optim import (adafactor, adamw, compress_state_init,
                         compressed_gradients, int8_compress,
                         int8_decompress, sgd, warmup_cosine)


@pytest.mark.parametrize("make_opt", [lambda: adamw(0.1),
                                      lambda: adafactor(0.5),
                                      lambda: sgd(0.05)])
def test_optimizer_decreases_quadratic(make_opt):
    opt = make_opt()
    params = {"w": jnp.asarray([3.0, -2.0, 5.0]),
              "m": {"b": jnp.asarray([[1.0, 2.0], [3.0, 4.0]])}}
    loss = lambda p: jnp.sum(p["w"] ** 2) + jnp.sum(p["m"]["b"] ** 2)
    state = opt.init(params)
    l0 = float(loss(params))
    step = jax.jit(lambda p, s, i: opt.update(jax.grad(loss)(p), s, p, i))
    for i in range(60):
        params, state = step(params, state, jnp.asarray(i))
    assert float(loss(params)) < 0.2 * l0


def test_adamw_state_structure_stable_under_jit():
    opt = adamw(1e-2)
    params = {"w": jnp.ones((4,))}
    state = opt.init(params)
    g = {"w": jnp.ones((4,))}
    p2, s2 = jax.jit(opt.update)(g, state, params, jnp.asarray(0))
    assert jax.tree_util.tree_structure(s2) == \
        jax.tree_util.tree_structure(state)


def test_adafactor_memory_is_factored():
    opt = adafactor(1e-3)
    params = {"w": jnp.zeros((128, 64))}
    state = opt.init(params)
    acc = state["acc"]["w"]
    assert acc["r"].shape == (128,) and acc["c"].shape == (64,)


def test_warmup_cosine_shape():
    fn = warmup_cosine(1.0, 10, 100)
    assert float(fn(jnp.asarray(0))) < 0.2
    assert float(fn(jnp.asarray(10))) == pytest.approx(1.0, abs=0.05)
    assert float(fn(jnp.asarray(99))) < 0.2


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 200))
def test_int8_roundtrip_bounded_error(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * (seed % 7 + 1)
    q, scale = int8_compress(x)
    y = int8_decompress(q, scale)
    assert float(jnp.abs(x - y).max()) <= float(scale) * 0.5 + 1e-6


def test_error_feedback_recovers_mean_gradient():
    """Constant gradient + error feedback: cumulative applied update
    converges to the true cumulative gradient (unbiasedness), including
    components far below one quantization step."""
    g = {"w": jnp.asarray([0.01, -0.02, 5.0, 0.004])}
    err = compress_state_init(g)
    total = jnp.zeros(4)
    n = 300
    for _ in range(n):
        dq, err = compressed_gradients(g, err)
        total = total + dq["w"]
    scale = 5.0 / 127.0
    np.testing.assert_allclose(np.asarray(total / n), np.asarray(g["w"]),
                               rtol=0.05, atol=2 * scale / n)


def test_checkpoint_roundtrip_and_retention():
    params = {"a": jnp.arange(6.0).reshape(2, 3),
              "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
              "lst": [jnp.zeros((2,)), jnp.ones((2,))]}
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep_last=2)
        for s in (1, 2, 3, 4):
            cm.save(s, params, extra={"loss": s * 1.0}, blocking=True)
        assert cm.steps() == [3, 4]
        tree, step, extra = cm.restore(params)
        assert step == 4 and extra["loss"] == 4.0
        np.testing.assert_array_equal(np.asarray(tree["a"]),
                                      np.asarray(params["a"]))
        assert tree["nested"]["b"].dtype == np.asarray(
            params["nested"]["b"]).dtype


def test_checkpoint_atomicity_tmpdir_cleanup():
    params = {"a": jnp.ones((2,))}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "step_1")
        save_tree(params, path, 1)
        assert not os.path.exists(path + ".tmp")
        tree, step, _ = restore_tree(path, params)
        assert step == 1


def test_data_determinism_and_sharding():
    base = dict(kind="lm", global_batch=8, seq_len=32, vocab_size=64,
                num_shards=2)
    p0 = make_pipeline(DataConfig(**base, shard_index=0))
    p1 = make_pipeline(DataConfig(**base, shard_index=1))
    a, b = p0.batch_at(3), p1.batch_at(3)
    assert a["tokens"].shape == (4, 32)
    assert not np.array_equal(a["tokens"], b["tokens"])  # different shards
    np.testing.assert_array_equal(a["tokens"], p0.batch_at(3)["tokens"])


def test_markov_data_is_learnable_structure():
    """Next-token conditional entropy well below uniform."""
    p = make_pipeline(DataConfig(kind="lm", global_batch=16, seq_len=128,
                                 vocab_size=256))
    toks = p.batch_at(0)["tokens"]
    # every (prev -> next) transition must be in the 8-branch table
    tbl = p.next_tokens
    ok = 0
    for row in toks:
        for t in range(1, len(row)):
            ok += row[t] in tbl[row[t - 1]]
    assert ok == toks.shape[0] * (toks.shape[1] - 1)
