"""Fused dual-engine SSA step (kernels/fused_ssa.py, engine.overlap).

Pins, in order of the stack:
  * the fused kernel is bitwise equal to the sequential oracle
    (``reference_bundle``) for both projection-epilogue families
    (BN — vision, RoPE — token/causal), including non-divisible L,
    all-zero spike rows, fully dark time slabs (the occupancy skip),
    and int8-quantized weights;
  * the executed-step counts output is exact: full-occupancy inputs
    count every sub-step, dark slabs are skipped and *not* counted;
  * ``resolve_overlap`` dispatch rules mirror ``resolve_sparse_path``:
    off by default, explicit honored (also under jit), auto fuses only
    on concrete inputs whose bundle flops clear ``min_flops``, tracer ->
    off;
  * whole-model logits are bitwise equal between ``overlap='off'`` and
    ``overlap='fused'`` on all three spikingformer configs, and whole-
    model gradients match bitwise (the custom VJP recomputes the
    sequential composition);
  * profiler annotations (``engine.annotate``) are metadata-only:
    annotated and unannotated runs are bitwise identical;
  * the per-head schedule extension keeps the scalar path numerically
    unchanged, and ``fused_step_metrics`` derives the measured hidden
    fraction from the kernel's counts.

Bit-exactness strategy matches tests/test_spike_decode.py: dyadic-grid
weights make fp32 accumulation order-exact, so equality is to the bit.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container ships the fixed-seed shim
    from _propcheck import given, settings, strategies as st

from repro.configs import get_config
from repro.core import dual_engine as de
from repro.core import engine as E
from repro.core.spiking import SpikingConfig
from repro.kernels.fused_ssa import fused_ssa, reference_bundle
from repro.models import registry


def _dyadic(key, shape):
    return (jax.random.randint(key, shape, -128, 128)
            .astype(jnp.float32)) * (2.0 ** -8)


def _spikes(key, shape, density=0.3):
    return (jax.random.uniform(key, shape) < density).astype(jnp.float32)


def _bn_aux(key, q_dim):
    k1, k2 = jax.random.split(key)
    mean = _dyadic(k1, (3, q_dim)) * 0.25
    var = jnp.abs(_dyadic(k2, (3, q_dim))) + 0.5
    scale = jnp.ones((3, q_dim)) * 1.25
    bias = jnp.full((3, q_dim), 0.0625)
    return jnp.stack([mean, var, scale, bias], axis=1)


def _rope_aux(seq, head_dim, theta=10000.0):
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = jnp.arange(seq, dtype=jnp.float32)[:, None] * freqs
    return jnp.stack([jnp.cos(ang), jnp.sin(ang)])


def _bundle(key, t, b, l, k, heads, hd, *, family, quant=False,
            dark_slab=False):
    ks = jax.random.split(key, 3)
    x = _spikes(ks[0], (t, b, l, k))
    x = x.at[:, :, min(2, l - 1)].set(0.0)          # an all-zero row
    if dark_slab:
        x = x.at[0, 0].set(0.0)                     # whole slab dark
    if quant:
        w3 = jax.random.randint(ks[1], (3, k, heads * hd), -128, 128
                                ).astype(jnp.int8).astype(jnp.float32)
        scale3 = jnp.abs(_dyadic(ks[2], (3, heads * hd))) + 0.5
    else:
        w3 = _dyadic(ks[1], (3, k, heads * hd))
        scale3 = None
    aux = _bn_aux(ks[2], heads * hd) if family == "bn" \
        else _rope_aux(l, hd)
    return x, w3, scale3, aux


SHAPES = [(2, 2, 13, 24, 4, 8),    # non-divisible L
          (2, 1, 16, 32, 2, 16),
          (3, 2, 9, 17, 3, 6)]     # odd everything (even head_dim)


@pytest.mark.parametrize("family", ["bn", "rope"])
@pytest.mark.parametrize("shape", SHAPES)
def test_fused_kernel_matches_oracle_bitwise(family, shape):
    t, b, l, k, heads, hd = shape
    scfg = SpikingConfig(time_steps=t)
    x, w3, scale3, aux = _bundle(jax.random.PRNGKey(hash(shape) % 997),
                                 t, b, l, k, heads, hd, family=family,
                                 dark_slab=True)
    kw = dict(family=family, num_heads=heads, head_dim=hd,
              scale=1.0 / math.sqrt(hd), causal=(family == "rope"))
    out, cnt = fused_ssa(x, w3, scale3, aux, 0.3, **kw)
    ref = reference_bundle(x, w3, scale3, aux, 0.3, scfg, **kw)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    cnt = np.asarray(cnt)
    # dark (t=0, b=0) slab is skipped: t*b - 1 executed per projection
    np.testing.assert_array_equal(cnt[:, :3], t * b - 1)
    np.testing.assert_array_equal(cnt[:, 3], 2 * t * b)


def test_fused_kernel_int8_weights_bitwise():
    t, b, l, k, heads, hd = 2, 2, 13, 24, 4, 8
    scfg = SpikingConfig(time_steps=t)
    x, w3, scale3, aux = _bundle(jax.random.PRNGKey(7), t, b, l, k,
                                 heads, hd, family="bn", quant=True)
    kw = dict(family="bn", num_heads=heads, head_dim=hd,
              scale=1.0 / math.sqrt(hd))
    out, _ = fused_ssa(x, w3, scale3, aux, 0.3, **kw)
    ref = reference_bundle(x, w3, scale3, aux, 0.3, scfg, **kw)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_fused_kernel_all_zero_input():
    t, b, l, k, heads, hd = 2, 1, 8, 16, 2, 8
    scfg = SpikingConfig(time_steps=t)
    x = jnp.zeros((t, b, l, k))
    w3 = _dyadic(jax.random.PRNGKey(3), (3, k, heads * hd))
    aux = _bn_aux(jax.random.PRNGKey(4), heads * hd)
    kw = dict(family="bn", num_heads=heads, head_dim=hd,
              scale=1.0 / math.sqrt(hd))
    out, cnt = fused_ssa(x, w3, None, aux, 0.3, **kw)
    ref = reference_bundle(x, w3, None, aux, 0.3, scfg, **kw)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    # every projection slab dark -> zero executed projection sub-steps
    np.testing.assert_array_equal(np.asarray(cnt)[:, :3], 0)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10 ** 6), st.floats(0.05, 0.6))
def test_fused_kernel_property_random_density(seed, density):
    t, b, l, k, heads, hd = 2, 2, 11, 20, 2, 8
    scfg = SpikingConfig(time_steps=t)
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = _spikes(ks[0], (t, b, l, k), density)
    w3 = _dyadic(ks[1], (3, k, heads * hd))
    aux = _bn_aux(ks[2], heads * hd)
    kw = dict(family="bn", num_heads=heads, head_dim=hd,
              scale=1.0 / math.sqrt(hd))
    out, _ = fused_ssa(x, w3, None, aux, 0.3, **kw)
    ref = reference_bundle(x, w3, None, aux, 0.3, scfg, **kw)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ---------------------------------------------------------------------------
# dispatch rules
# ---------------------------------------------------------------------------


BIG = 1 << 23


def test_resolve_overlap_modes():
    x = jnp.ones((4, 4))
    assert E.resolve_overlap(None, x, BIG) == "off"
    off = E.EngineConfig(overlap="off")
    fused = E.EngineConfig(overlap="fused")
    auto = E.EngineConfig(overlap="auto")
    assert E.resolve_overlap(off, x, BIG) == "off"
    assert E.resolve_overlap(fused, x, 0) == "fused"
    assert E.resolve_overlap(auto, x, BIG) == "fused"
    assert E.resolve_overlap(auto, x, 10) == "off"      # below min_flops
    assert E.resolve_overlap(auto, None, BIG) == "off"  # no concrete input

    seen = []

    @jax.jit
    def f(u):
        seen.append((E.resolve_overlap(auto, u, BIG),
                     E.resolve_overlap(fused, u, 0)))
        return u

    f(x)
    assert seen == [("off", "fused")]  # tracer -> off; explicit honored


def test_engine_config_rejects_bad_overlap():
    with pytest.raises(ValueError):
        E.EngineConfig(overlap="pipelined")


# ---------------------------------------------------------------------------
# whole-model parity (logits + grads) and annotation bitwise-neutrality
# ---------------------------------------------------------------------------


SPIKING_ARCHS = ["spikingformer-4-256", "spikingformer-8-512",
                 "spikingformer-lm"]


def _model_setup(arch):
    cfg = get_config(arch, smoke=True)
    params = jax.tree_util.tree_map(
        lambda a: jnp.round(a * 256) / 256
        if jnp.issubdtype(a.dtype, jnp.floating) else a,
        registry.init(cfg, jax.random.PRNGKey(0)))
    if cfg.family == "dense":
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                              (2, 16), 0, cfg.vocab_size)}
    else:
        batch = {"images": jax.random.uniform(
            jax.random.PRNGKey(1),
            (2, cfg.vision.img_size, cfg.vision.img_size,
             cfg.vision.in_channels))}
    return cfg, params, batch


@pytest.mark.parametrize("arch", SPIKING_ARCHS)
def test_model_logits_bitwise_fused_vs_off(arch):
    cfg, params, batch = _model_setup(arch)
    outs = {}
    for ov in ("off", "fused"):
        with E.use_engine(cfg.engine.replace(overlap=ov)):
            logits, _ = registry.forward(params, cfg, batch)
        outs[ov] = np.asarray(logits)
    np.testing.assert_array_equal(outs["off"], outs["fused"])


@pytest.mark.parametrize("arch", ["spikingformer-4-256", "spikingformer-lm"])
def test_model_grads_bitwise_fused_vs_off(arch):
    cfg, params, batch = _model_setup(arch)

    def loss(p, eng):
        with E.use_engine(eng):
            logits, _ = registry.forward(p, cfg, batch)
        return jnp.sum(logits ** 2) * 1e-3

    grads = {ov: jax.grad(loss)(params, cfg.engine.replace(overlap=ov))
             for ov in ("off", "fused")}
    for a, b in zip(jax.tree_util.tree_leaves(grads["off"]),
                    jax.tree_util.tree_leaves(grads["fused"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_model_logits_bitwise_under_jit():
    """Explicit overlap='fused' is honored under jit (the forward runs
    inside the block scan, so the bundle input is always traced)."""
    cfg, params, batch = _model_setup("spikingformer-4-256")
    outs = {}
    for ov in ("off", "fused"):
        eng = cfg.engine.replace(overlap=ov)

        @jax.jit
        def f(p):
            with E.use_engine(eng):
                return registry.forward(p, cfg, batch)[0]

        outs[ov] = np.asarray(f(params))
    np.testing.assert_array_equal(outs["off"], outs["fused"])


@pytest.mark.parametrize("ov", ["off", "fused"])
def test_annotations_are_bitwise_neutral(ov):
    cfg, params, batch = _model_setup("spikingformer-4-256")
    eng = cfg.engine.replace(overlap=ov)
    with E.use_engine(eng):
        annotated, _ = registry.forward(params, cfg, batch)
        with E.disable_annotations():
            plain, _ = registry.forward(params, cfg, batch)
    np.testing.assert_array_equal(np.asarray(annotated), np.asarray(plain))


# ---------------------------------------------------------------------------
# schedule extension: scalar path pinned, per-head + measured metrics
# ---------------------------------------------------------------------------


def test_measured_schedule_scalar_path_pinned():
    ts, tb, heads = 1.3, 0.7, 8
    se, be, overlapped, serial = de.measured_schedule(ts, tb, heads)
    # the original two-scalar arithmetic, replayed op-for-op
    t_sparse = 0.0
    qk_done, v_done = {}, {}
    for h in range(heads):
        for name in ("Q", "K", "V"):
            t_sparse += ts
            if name == "K":
                qk_done[h] = t_sparse
            if name == "V":
                v_done[h] = t_sparse
    t_bin = 0.0
    for h in range(heads):
        t_bin = max(t_bin, qk_done[h]) + tb
        t_bin = max(t_bin, v_done[h]) + tb
    assert overlapped == max(t_sparse, t_bin)
    assert serial == t_sparse + 2 * tb * heads
    assert len(se) == 3 * heads and len(be) == 2 * heads


def test_measured_schedule_per_head_matches_uniform_scalar():
    heads = 4
    uniform = de.measured_schedule(2.0, 1.0, heads)
    per_head = de.measured_schedule([(2.0, 2.0, 2.0)] * heads,
                                    [(1.0, 1.0)] * heads, heads)
    assert uniform[2] == per_head[2]          # overlapped makespan
    assert uniform[3] == per_head[3]          # serial total
    assert uniform[0] == per_head[0] and uniform[1] == per_head[1]


def test_measured_schedule_rejects_length_mismatch():
    with pytest.raises(ValueError):
        de.measured_schedule([1.0, 2.0], 1.0, heads=4)


def test_schedule_metrics_utilization():
    m = de.schedule_metrics(1.0, 1.0, heads=4)
    assert 0.0 < m["hidden_fraction"] < 1.0
    assert 0.0 < m["sparse_util"] <= 1.0
    assert 0.0 < m["binary_util"] <= 1.0
    assert m["hidden_fraction"] == pytest.approx(
        de.measured_overlap_efficiency(1.0, 1.0, 4))
    # sparse engine never stalls in the Fig. 5 schedule
    assert m["sparse_util"] == pytest.approx(
        3 * 4 * 1.0 / m["overlapped"])


def test_fused_step_metrics_from_kernel_counts():
    t, b, l, k, heads, hd = 2, 2, 16, 32, 2, 16
    x, w3, _, aux = _bundle(jax.random.PRNGKey(11), t, b, l, k, heads, hd,
                            family="bn", dark_slab=True)
    _, cnt = fused_ssa(x, w3, None, aux, 0.3, family="bn",
                       num_heads=heads, head_dim=hd,
                       scale=1.0 / math.sqrt(hd))
    m = de.fused_step_metrics(np.asarray(cnt), seq=l, k_dim=k, head_dim=hd,
                              t_steps=t, batch=b)
    assert m["executed_attn"] == 2 * t * b * heads
    # the dark slab was skipped in all three projections of both heads
    assert m["executed_q"] == (t * b - 1) * heads
    assert m["proj_skip_fraction"] == pytest.approx(1.0 / (t * b))
    assert 0.0 < m["hidden_fraction"] < 1.0
    assert m["step_reduction"] > 0.0
    assert m["possible_steps"] == 5 * t * b * heads
