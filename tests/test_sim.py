"""Hardware-simulator anchors: the paper's own experimental claims."""
import numpy as np
import pytest

from repro.core.dual_engine import (AttentionWorkload, EngineParallelism,
                                    complexity_reduction, pipeline_schedule,
                                    required_binary_parallelism)
from repro.sim import balance_sim as bs, decoder_sim as ds
from repro.sim import perf_model as pm
from repro.sim import resource_model as rm


def test_fig12_optimal_pci_tracks_sparsity():
    """Optimal P_Ci ~= G / (1 - sparsity) (paper: G=4 -> 16 at 75%)."""
    _, best = ds.sweep_fig12(g_values=(2, 4, 8),
                             p_ci_values=(4, 8, 16, 32, 64), sparsity=0.75)
    assert best[2] == 8 and best[4] == 16 and best[8] == 32


def test_fig12_max_f_scales_linearly_with_pci():
    out, best = ds.sweep_fig12(g_values=(2, 4, 8, 16),
                               p_ci_values=(8, 16, 32, 64), sparsity=0.75)
    # optimal P_Ci keeps growing with G (no saturation); at G=16 the sim
    # sits right at the G/(1-s) knee where the ceil penalty makes 32 and
    # 64 near-equal — accept either ("near-optimal", paper's wording)
    assert best[16] >= 32
    assert out[16][64] > 0.9  # 64 within 10% of the G=16 optimum


def test_fig13a_two_workers_reach_80pct_of_peak():
    for g, p_ci in ((4, 16), (8, 32)):
        r = ds.sweep_fig13a(g, p_ci)
        assert r[2] / max(r.values()) >= 0.80, (g, r)
        # monotone improvement with more workers
        keys = sorted(r)
        assert all(r[a] <= r[b] * 1.02 for a, b in zip(keys, keys[1:]))


def test_decoder_latency_zero_word_costs_one_cycle():
    cfg = ds.DecoderConfig(p_ci=16, m_lanes=4, p_wo=1)
    assert ds.simulate_latency(np.zeros(10, int), cfg) == 10


def test_fig13c_scaling_ours_beats_crossbar():
    ours, xbar = bs.scaling_curve()
    ours_loss = 1 - ours[128]
    xbar_loss = 1 - xbar[128]
    # paper: 13.17% vs 70.68%; sim calibration bands
    assert ours_loss < 0.25, ours_loss
    assert 0.55 < xbar_loss < 0.90, xbar_loss
    assert xbar_loss > 3 * ours_loss


def test_fig13b_unified_faster_at_equal_bandwidth():
    for bm in (1, 2, 4, 8):
        res = bs.compare(n_pes=16, n_banks=bm, throughput=4)
        assert res.speedup > 1.3, (bm, res)


def test_observation1_grid_popcount_correlation():
    rng = np.random.default_rng(0)
    pc = bs.spike_chunks(64, 256, 16, 0.75, rng)
    cross_std = pc.std(axis=0).mean()
    assert cross_std < 0.06 * 16  # ~3% of theoretical max, paper Fig 7B


def test_fig9_lut6_andpopcount_claims():
    cmp18 = rm.and_popcount_comparison(18)
    assert cmp18["ours_depth"] == 2            # paper: 5 -> 2 stages
    assert cmp18["naive_depth"] >= 5
    assert 0.45 <= cmp18["lut_reduction"] <= 0.60  # paper: 52%
    # reduction holds across widths
    for n in (12, 24, 32, 64):
        c = rm.and_popcount_comparison(n)
        assert c["ours_luts"] < c["naive_luts"]
        assert c["ours_depth"] < c["naive_depth"]


def test_tableV_dsp_counts():
    assert rm.sparse_engine_dsps(rm.HardwareConfig(g=4)) == 288
    assert rm.sparse_engine_dsps(rm.HardwareConfig(g=2)) == 128
    assert rm.binary_engine_dsps(rm.HardwareConfig()) == 16


def test_tableVI_lut_model_within_10pct():
    hw4 = rm.HardwareConfig(g=4, p_wo=2)
    hw2 = rm.HardwareConfig(g=2, p_wo=2)
    assert abs(rm.decoder_luts(hw4) - 1442) / 1442 < 0.10
    assert abs(rm.decoder_luts(hw2) - 1306) / 1306 < 0.10
    assert abs(rm.balancer_luts(hw4) - 33536) / 33536 < 0.10
    assert abs(rm.balancer_luts(hw2) - 17280) / 17280 < 0.10


def test_dsp_savings_law():
    sv = rm.dsp_savings(rm.HardwareConfig(g=2))
    assert sv["dsps_saved"] == 896 and sv["net_win_luts"] > 0
    sv4 = rm.dsp_savings(rm.HardwareConfig(g=4))
    assert sv4["dsps_saved"] == 768


def test_tableIV_fireflyt_rows_within_tolerance():
    cifar = pm.evaluate("cifarnet", rm.HardwareConfig(g=2))
    assert abs(cifar.gops - 3630) / 3630 < 0.10
    assert abs(cifar.energy_eff - 978.61) / 978.61 < 0.10
    sf8 = pm.evaluate("spikingformer-8-512", rm.HardwareConfig(g=4))
    assert abs(sf8.gops - 3397) / 3397 < 0.15
    sf4 = pm.evaluate("spikingformer-4-256", rm.HardwareConfig(g=4))
    assert abs(sf4.gops - 3029) / 3029 < 0.15


def test_headline_ratios():
    r = pm.headline_ratios()
    assert abs(r["energy_vs_fireflyv2"] - 1.39) < 0.12
    assert abs(r["energy_vs_spiketa"] - 2.40) < 0.20
    assert abs(r["dsp_vs_fireflyv2"] - 4.21) < 0.35
    assert abs(r["dsp_vs_spiketa"] - 7.10) < 0.60


def test_eq4_sizing_hides_attention():
    """Engines sized per Eq. 4 => overlapped time ~= projection time."""
    w = AttentionWorkload(T_s=4, F_h=14, F_w=14, C_i=512, P_Co=64, heads=8)
    p = EngineParallelism(P_Ts=2, P_Fx=4, P_Ci=16, P_Co=64,
                          P_Bm=8, P_Bn=8, P_Bk=32)
    need = required_binary_parallelism(w, p)
    assert 0.5 * need <= p.P_b <= 2.5 * need  # the paper's sizing regime
    _, _, overlapped, serial = pipeline_schedule(w, p)
    assert overlapped < serial
    assert overlapped <= 1.25 * 3 * w.heads * (w.W_s() / p.P_s)


def test_complexity_reduction_formula():
    w = AttentionWorkload(T_s=4, F_h=8, F_w=8, C_i=256, P_Co=32, heads=8)
    serial, overlapped = complexity_reduction(w)
    assert serial == 3 * 4 * 64 * 256 ** 2 + 2 * 4 * 64 ** 2 * 256
    assert overlapped == 3 * 4 * 64 * 256 ** 2
