"""Model registry: family -> (init, forward, init_cache, decode_step).

Uniform API:
  init(cfg, key)                      -> params pytree
  forward(params, cfg, batch, train=) -> (logits, aux)
  init_cache(cfg, batch, max_len, ...)-> decode cache pytree (LM families)
  decode_step(params, cfg, cache, tokens, pos) -> (logits, new_cache)

Vision/classification families (spikingformer, cifarnet) carry BatchNorm
running stats: ``init_state(cfg)`` + aux['state'].
"""
from __future__ import annotations

from types import ModuleType
from typing import Dict

from repro.configs.base import ModelConfig
from . import transformer, moe, rwkv, hybrid, encdec, vlm, spikingformer

FAMILIES: Dict[str, ModuleType] = {
    "dense": transformer,
    "moe": moe,
    "rwkv": rwkv,
    "hybrid": hybrid,
    "encdec": encdec,
    "vlm": vlm,
    "spikingformer": spikingformer,
    "cifarnet": spikingformer,
}

# families whose long_500k cell is skipped (pure full attention; DESIGN.md §5)
NO_LONG_CONTEXT = {"nemotron-4-15b", "granite-20b", "whisper-small",
                   "kimi-k2-1t-a32b", "deepseek-moe-16b"}
# families without an autoregressive decode step
NO_DECODE = {"spikingformer", "cifarnet"}
# families whose decode_step carries per-slot state: vectorized positions
# (pos: (B,)), per-slot cache validity tags, chunked multi-token bites
# (n_tok), and slot invalidation — the contract the continuous-batching
# orchestrator (launch/serve.py) requires
SLOTTED_DECODE = {"dense", "vlm"}


def family_module(cfg: ModelConfig) -> ModuleType:
    try:
        return FAMILIES[cfg.family]
    except KeyError:
        raise ValueError(f"unknown family {cfg.family!r}") from None


def init(cfg: ModelConfig, key):
    return family_module(cfg).init(cfg, key)


def forward(params, cfg: ModelConfig, batch, *, train: bool = False, **kw):
    return family_module(cfg).forward(params, cfg, batch, train=train, **kw)


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int, batch=None,
               params=None, chunk_headroom: int = 0):
    mod = family_module(cfg)
    if chunk_headroom:
        assert supports_slots(cfg), \
            f"{cfg.family} decode takes no chunked-prefill bites"
        return mod.init_cache(cfg, batch_size, max_len, batch=batch,
                              params=params, chunk_headroom=chunk_headroom)
    return mod.init_cache(cfg, batch_size, max_len, batch=batch,
                          params=params)


def decode_step(params, cfg: ModelConfig, cache, tokens, pos, n_tok=None):
    mod = family_module(cfg)
    if n_tok is not None:
        return mod.decode_step(params, cfg, cache, tokens, pos, n_tok=n_tok)
    return mod.decode_step(params, cfg, cache, tokens, pos)


def invalidate_slots(cfg: ModelConfig, cache, slot_mask):
    """Reset the validity tags of masked slots (continuous-batching
    admission). Slotted-decode families only."""
    assert supports_slots(cfg), f"{cfg.family} has no per-slot decode state"
    return family_module(cfg).invalidate_slots(cache, slot_mask)


def has_decode(cfg: ModelConfig) -> bool:
    return cfg.family not in NO_DECODE


def supports_slots(cfg: ModelConfig) -> bool:
    return cfg.family in SLOTTED_DECODE


def init_state(cfg: ModelConfig):
    if cfg.family in ("spikingformer", "cifarnet"):
        return spikingformer.init_state(cfg)
    return None
