"""LR schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else \
            jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(1.0, (step + 1) / max(1, warmup_steps))
        prog = jnp.clip((step - warmup_steps) /
                        max(1, total_steps - warmup_steps), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * \
            (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup_steps, warm, peak_lr * cos)
    return fn
