"""Dual-engine latency-hiding pipeline model (paper Section III-C, Eq. 3/4).

FireFly-T overlaps the sparse engine (Q/K/V projections) with the binary
engine (QK^T, QK^T V) across attention heads. This module is the analytic +
discrete-event model of that schedule; it is used by:

* ``repro.sim.perf_model``    — Table IV throughput/energy reproduction,
* ``benchmarks/fig5_pipeline``— the spatial-temporal overlap diagram,
* the engine-sizing rule Eq. 4 used to pick ``P_B*`` for a network.

On TPU the same overlap re-appears as HBM-prefetch ∥ MXU pipelining inside
the fused attention kernel and as compute/collective overlap at the
distribution layer (see DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Tuple


@dataclasses.dataclass(frozen=True)
class EngineParallelism:
    """Hardware parallelism knobs (Table II)."""
    P_Ts: int = 2
    P_Fx: int = 4
    P_Ci: int = 16
    P_Co: int = 64
    # binary engine systolic array + inner-product width
    P_Bm: int = 4
    P_Bn: int = 4
    P_Bk: int = 32

    @property
    def P_s(self) -> int:
        return self.P_Ts * self.P_Fx * self.P_Ci * self.P_Co

    @property
    def P_b(self) -> int:
        return self.P_Bm * self.P_Bn * self.P_Bk


@dataclasses.dataclass(frozen=True)
class AttentionWorkload:
    """Per-head attention workload (Eq. 3)."""
    T_s: int
    F_h: int
    F_w: int
    C_i: int          # embedding dim d
    P_Co: int         # output-channel tile == per-head dim in the schedule
    heads: int = 8

    @property
    def L(self) -> int:
        return self.F_h * self.F_w

    def W_s(self) -> int:
        """Sparse-engine work per head per projection (MACs)."""
        return self.T_s * self.L * self.C_i * self.P_Co

    def W_b(self) -> int:
        """Binary-engine work per head per attention matmul (MACs)."""
        return self.T_s * self.L * self.L * self.P_Co


def required_binary_parallelism(w: AttentionWorkload, p: EngineParallelism) -> float:
    """Eq. 4: P_b ~= 2/3 * (Fh*Fw / Ci) * P_s for balanced overlap."""
    return 2.0 / 3.0 * (w.L / w.C_i) * p.P_s


def _event_schedule(ts: float, tb: float, heads: int
                    ) -> Tuple[List[tuple], List[tuple], float, float]:
    """Core event loop shared by the analytic and measured schedules:
    the sparse engine serially computes Q_h, K_h, V_h per head (``ts``
    each); the binary engine computes ``QK^T_h`` once Q_h,K_h are done
    and ``QK^T V_h`` once V_h is done (``tb`` each)."""
    sparse_events, binary_events = [], []
    t_sparse = 0.0
    qk_done = {}
    v_done = {}
    for h in range(heads):
        for name in ("Q", "K", "V"):
            sparse_events.append((f"{name}{h}", t_sparse, t_sparse + ts))
            t_sparse += ts
            if name == "K":
                qk_done[h] = t_sparse
            if name == "V":
                v_done[h] = t_sparse
    t_bin = 0.0
    for h in range(heads):
        start = max(t_bin, qk_done[h])
        binary_events.append((f"QK^T {h}", start, start + tb))
        t_bin = start + tb
        start = max(t_bin, v_done[h])
        binary_events.append((f"QK^TV {h}", start, start + tb))
        t_bin = start + tb

    total_overlapped = max(t_sparse, t_bin if binary_events else 0.0)
    total_serial = t_sparse + 2 * tb * heads
    return sparse_events, binary_events, total_overlapped, total_serial


def pipeline_schedule(w: AttentionWorkload, p: EngineParallelism,
                      sparsity: float = 0.0
                      ) -> Tuple[List[tuple], List[tuple], int, int]:
    """Discrete-event schedule of the latency-hiding pipeline (Fig. 5).

    Op latencies come from the analytic MAC model (Eq. 3 work over
    Table II parallelism; sparse throughput scales with input density
    when skipping is on). Returns (sparse_events, binary_events,
    total_overlapped, total_serial); events are (name, start, end) in
    cycles.
    """
    ts = w.W_s() / (p.P_s / max(1e-9, 1.0 - sparsity))  # sparse op latency
    tb = w.W_b() / p.P_b                                # binary op latency
    se, be, overlapped, serial = _event_schedule(ts, tb, w.heads)
    return se, be, math.ceil(overlapped), math.ceil(serial)


def measured_schedule(sparse_op_us: float, binary_op_us: float,
                      heads: int = 8
                      ) -> Tuple[List[tuple], List[tuple], float, float]:
    """Fig. 5 schedule fed with *measured* engine timings instead of the
    analytic MAC model — e.g. the per-call medians
    ``benchmarks/dual_engine_bench.py`` writes to
    ``artifacts/dual_engine_bench.json`` (``sparse_us`` from the matmul
    sweep, ``mxu_us`` from the attention sweep). Events are in the same
    unit as the inputs (microseconds); returns (sparse_events,
    binary_events, total_overlapped, total_serial).
    """
    return _event_schedule(float(sparse_op_us), float(binary_op_us), heads)


def measured_overlap_efficiency(sparse_op_us: float, binary_op_us: float,
                                heads: int = 8) -> float:
    """Fraction of the serial dual-engine latency the overlap hides,
    from measured timings: 1 - overlapped/serial."""
    _, _, overlapped, serial = measured_schedule(sparse_op_us,
                                                 binary_op_us, heads)
    if serial <= 0:
        return 0.0
    return 1.0 - overlapped / serial


def pipeline_efficiency(w: AttentionWorkload, p: EngineParallelism,
                        sparsity: float = 0.0) -> float:
    """Fraction of attention latency hidden: 1 -> perfect (O(3TsLd^2))."""
    _, _, overlapped, serial = pipeline_schedule(w, p, sparsity)
    ideal = 3 * w.heads * (w.W_s() / (p.P_s / max(1e-9, 1.0 - sparsity)))
    if overlapped <= 0:
        return 1.0
    return min(1.0, ideal / overlapped)


def complexity_reduction(w: AttentionWorkload) -> Tuple[int, int]:
    """(serial, overlapped) op counts: O(3TsLd^2 + 2TsL^2 d) -> O(3TsLd^2).

    Uses d == heads * P_Co as the full embedding dim.
    """
    d = w.C_i
    serial = 3 * w.T_s * w.L * d * d + 2 * w.T_s * w.L * w.L * d
    overlapped = 3 * w.T_s * w.L * d * d
    return serial, overlapped
