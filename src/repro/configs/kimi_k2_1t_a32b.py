"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384 experts top-8 + 1 shared, first layer dense —
trillion-param MoE (paper-table config) [arXiv:2501.*].

~1.04T parameters; active ~32B/token. Uses Adafactor (launch layer
override) — Adam fp32 moments would need 8 TB.
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8, head_dim=112,
    d_ff=2048, vocab_size=163840,
    attn_type="full", act="silu", gated=True, rope_theta=50000.0,
    moe=MoEConfig(num_experts=384, top_k=8, d_ff_expert=2048, num_shared=1,
                  first_k_dense=1, first_dense_ff=18432,
                  capacity_factor=1.25),
)

SMOKE = CONFIG.replace(
    num_layers=3, d_model=96, num_heads=4, num_kv_heads=2, head_dim=24,
    d_ff=64, vocab_size=512, dtype="float32", remat=False,
    moe=MoEConfig(num_experts=16, top_k=4, d_ff_expert=64, num_shared=1,
                  first_k_dense=1, first_dense_ff=192,
                  capacity_factor=8.0))
