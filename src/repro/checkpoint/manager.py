"""Sharded, async checkpointing with elastic restore.

Format: one directory per step containing
  manifest.json  — treedef (path-keyed), shapes, dtypes, container kinds,
                   step metadata
  <leaf-id>.npy  — one file per leaf (every leaf saved in its dtype —
                   float, int8 weight codes, packed-int4 uint8 nibbles,
                   packed-KV uint32 words all round-trip bitwise)

Quantized trees (repro.quant: {"qw": int8|uint8, "scale": fp32} linears)
are first-class: the int payload is the on-disk payload (a quantized
checkpoint really is ~4x/~8x smaller — see ``dir_nbytes``), scales ride
the same manifest, and ``extra={"quant": ...}`` records the datapath so a
serving loader can validate dtype expectations before restore. Restore
works against a template pytree *or* template-free (``template=None``):
the manifest's per-leaf container kinds rebuild the nested dict/list
structure — which is how a server loads a quantized tree whose structure
(qw/scale vs w) differs from anything ``registry.init`` produces.

Design points for 1000+ node scale (implemented here single-controller,
interfaces multi-host ready):
  * async save — the host copy + write happen on a background thread; the
    train loop only blocks on the previous save (double buffering);
  * atomicity — writes go to ``<dir>.tmp`` then os.replace, so a crash
    mid-save never corrupts the latest checkpoint;
  * elastic restore — leaves are stored as full logical arrays; on restore
    they are device_put against *target* shardings, so a checkpoint taken
    on a 16x16 mesh restores onto 2x16x16 (or 1 CPU device) unchanged;
  * retention — keep last N plus every K-th "durable" step.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import ml_dtypes
import numpy as np

# numpy can't natively save/load ml_dtypes (bfloat16 etc.) — store the raw
# bits and the logical dtype in the manifest, view back on restore.
_EXTENDED_DTYPES = {"bfloat16": (ml_dtypes.bfloat16, np.uint16),
                    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
                    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8)}


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}

    def walk(path, node):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(path + (str(k),), v)
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(path + (str(i),), v)
        else:
            flat["/".join(path)] = node
    walk((), tree)
    return flat


def _container_kinds(tree) -> Dict[str, str]:
    """Internal-node kinds by path ('' = root): every container is
    recorded — including empty ones, which have no leaf to imply them —
    so the tree rebuilds with no template."""
    kinds: Dict[str, str] = {}

    def walk(path, node):
        key = "/".join(path)
        if isinstance(node, dict):
            kinds[key] = "dict"
            for k, v in node.items():
                walk(path + (str(k),), v)
        elif isinstance(node, (list, tuple)):
            kinds[key] = "tuple" if isinstance(node, tuple) else "list"
            for i, v in enumerate(node):
                walk(path + (str(i),), v)
    walk((), tree)
    return kinds


def _unflatten_from_manifest(flat: Dict[str, Any],
                             kinds: Dict[str, str]):
    """Template-free rebuild: seed every recorded container (so empty
    lists/dicts survive the round trip), nest leaves by '/'-split paths,
    then turn list/tuple nodes (children keyed '0'..'n-1') back into
    sequences."""
    root: Dict[str, Any] = {}

    def ensure(parts):
        node = root
        for p in parts:
            node = node.setdefault(p, {})
        return node

    for path in kinds:
        if path:
            ensure(path.split("/"))
    for path, leaf in flat.items():
        parts = path.split("/")
        ensure(parts[:-1])[parts[-1]] = leaf

    def rebuild(path: str, node):
        if not isinstance(node, dict):
            return node
        built = {k: rebuild(f"{path}/{k}" if path else k, v)
                 for k, v in node.items()}
        kind = kinds.get(path)
        if kind in ("list", "tuple"):
            seq = [built[str(i)] for i in range(len(built))]
            return tuple(seq) if kind == "tuple" else seq
        return built
    return rebuild("", root)


def _unflatten(template, flat: Dict[str, Any]):
    def walk(path, node):
        if isinstance(node, dict):
            return {k: walk(path + (str(k),), v) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(path + (str(i),), v) for i, v in enumerate(node)]
        if isinstance(node, tuple):
            return tuple(walk(path + (str(i),), v)
                         for i, v in enumerate(node))
        return flat["/".join(path)]
    return walk((), template)


def save_tree(tree, directory: str, step: int, extra: Optional[dict] = None):
    tmp = directory + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten_with_paths(tree)
    manifest = {"step": step, "leaves": {}, "extra": extra or {},
                "containers": _container_kinds(tree)}
    for i, (path, leaf) in enumerate(sorted(flat.items())):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf{i:05d}.npy"
        dtype_name = str(arr.dtype)
        if dtype_name in _EXTENDED_DTYPES:
            arr = arr.view(_EXTENDED_DTYPES[dtype_name][1])
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][path] = {"file": fname,
                                    "shape": list(arr.shape),
                                    "dtype": dtype_name,
                                    "nbytes": int(arr.nbytes)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(directory):
        shutil.rmtree(directory)
    os.replace(tmp, directory)


def restore_tree(directory: str, template=None, shardings=None):
    """Restore against a template pytree, or with ``template=None``
    rebuild the structure from the manifest's container kinds (quantized
    / legacy-structure checkpoints); ``shardings`` (same structure,
    jax.sharding.Sharding leaves) enables elastic re-mesh on load."""
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    flat = {}
    for path, info in manifest["leaves"].items():
        arr = np.load(os.path.join(directory, info["file"]))
        if info["dtype"] in _EXTENDED_DTYPES:
            arr = arr.view(_EXTENDED_DTYPES[info["dtype"]][0])
        flat[path] = arr
    if template is None:
        if "containers" not in manifest:
            raise ValueError(
                f"checkpoint {directory} predates container-kind "
                f"manifests: template-free restore cannot distinguish "
                f"lists from dicts — pass a template pytree")
        tree = _unflatten_from_manifest(flat, manifest["containers"])
    else:
        tree = _unflatten(template, flat)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda leaf, s: jax.device_put(leaf, s), tree, shardings)
    return tree, manifest["step"], manifest.get("extra", {})


def dir_nbytes(directory: str) -> int:
    """On-disk payload bytes of a checkpoint (leaf files only — the
    measured number behind the quantized-checkpoint compression report)."""
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    return sum(os.path.getsize(os.path.join(directory, info["file"]))
               for info in manifest["leaves"].values())


class CheckpointManager:
    """Async double-buffered checkpoint manager with retention policy."""

    def __init__(self, root: str, keep_last: int = 3,
                 durable_every: int = 0):
        self.root = root
        self.keep_last = keep_last
        self.durable_every = durable_every
        self._thread: Optional[threading.Thread] = None
        os.makedirs(root, exist_ok=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def steps(self):
        out = []
        for name in os.listdir(self.root):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree, extra: Optional[dict] = None,
             blocking: bool = False):
        self.wait()  # double buffering: block only on the previous save
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            save_tree(host_tree, self._step_dir(step), step, extra)
            self._gc()

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def restore(self, template=None, step: Optional[int] = None,
                shardings=None):
        step = step if step is not None else self.latest_step()
        if step is None:
            return None
        return restore_tree(self._step_dir(step), template, shardings)

    def _gc(self):
        steps = self.steps()
        keep = set(steps[-self.keep_last:])
        if self.durable_every:
            keep |= {s for s in steps if s % self.durable_every == 0}
        for s in steps:
            if s not in keep:
                shutil.rmtree(self._step_dir(s), ignore_errors=True)
