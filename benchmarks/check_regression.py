"""Bench-regression gate: fresh smoke artifacts vs committed baselines.

Four PRs of bench artifacts have been *uploaded* by CI without anything
reading them; this script makes CI *gate* on them. It extracts the
deterministic metrics from ``artifacts/*.json`` (skip fractions, modeled
speedups, MAC reductions, footprint compression, schedule agreement,
wave reductions — never wall-clock, which is CI noise), compares each
against the committed view in ``benchmarks/baselines/``, and exits
non-zero on drift outside the stated tolerances.

The extracted metrics are deterministic on any backend: they derive from
fixed PRNG seeds and modeled/counted quantities (occupancy maps, bucket
schedules, byte counts, wave counts), not from timing. Baselines are the
*smoke* variants CI produces; regenerate them after an intentional
change with

    PYTHONPATH=src python benchmarks/run.py --smoke
    PYTHONPATH=src python benchmarks/serve_bench.py --smoke
    python benchmarks/check_regression.py --update-baselines

Comparison rules: every baseline metric must exist in the fresh artifact
and sit within tolerance (a vanished metric IS drift); fresh metrics
absent from the baseline are ignored, so local full (non ``--smoke``)
runs — a superset of the smoke sweep — still pass. Two extra guards:
baseline key families outside the artifact's ``KNOWN_PREFIXES``
registry fail loud as *stale baselines* (the bench stopped emitting
that family — one targeted failure naming it, not a generic "vanished"
line per key), and ``FLOORS`` are absolute acceptance thresholds
checked against the fresh artifact itself — they hold even at
``--update-baselines`` time, so a regeneration can never ratify a
below-floor value. To keep that superset
property, only *sweep-independent* metrics are gated: per-row keys (a
full sweep adds rows, never changes a smoke row) and whole-config
quantities (footprint compression, PTQ logit MAE, wave reduction) —
never sweep aggregates like maxima or means over however many points
happened to run.

Usage: python benchmarks/check_regression.py [--artifacts DIR]
           [--baselines DIR] [--update-baselines]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# tolerance kinds: ("abs", x) -> |fresh - base| <= x;
#                  ("rel", x) -> |fresh - base| <= x * max(|base|, eps);
#                  ("exact",) -> fresh == base
ABS, REL, EXACT = "abs", "rel", "exact"


def _row_key(prefix, row, fields):
    parts = [prefix] + [str(row[f]).replace(" ", "") for f in fields]
    return "/".join(parts)


def extract_dual_engine(blob):
    """Sparse-engine sweep: per-point tile skip + modeled speedup, the
    tile-vs-decoded ragged-pattern rows, and the fused layer step's
    measured overlap (executed-step counts + schedule ratios)."""
    out = {}
    for r in blob.get("rows", []):
        key = _row_key("linear", r, ("shape", "block", "sparsity"))
        out[key + "/skip_fraction"] = (r["skip_fraction"], (ABS, 0.02))
        out[key + "/modeled_speedup"] = (r["modeled_speedup"], (REL, 0.05))
    for r in blob.get("sparse_path_rows", []):
        key = _row_key("sparse_path", r, ("pattern", "shape"))
        out[key + "/tile_skip_fraction"] = (
            r["tile_skip_fraction"], (ABS, 0.02))
        out[key + "/decoded_mac_reduction"] = (
            r["decoded_mac_reduction"], (ABS, 0.03))
        out[key + "/decoded_modeled_speedup"] = (
            r["decoded_modeled_speedup"], (REL, 0.05))
        out[key + "/sched_agreement"] = (r["sched_agreement"], (ABS, 0.15))
        out[key + "/auto_choice"] = (r["auto_choice"], (EXACT,))
    for r in blob.get("fused_rows", []):
        # fused SSA bundle: everything here derives from the kernel's
        # executed-step counts on fixed-seed inputs — deterministic on
        # any backend. Executed counts are gated exactly; the schedule
        # ratios get a hair of float tolerance. Wall clock never gated.
        key = _row_key("fused", r, ("config", "shape"))
        for f in ("executed_q", "executed_k", "executed_v",
                  "executed_attn", "executed_steps", "possible_steps"):
            out[key + f"/{f}"] = (r[f], (EXACT,))
        out[key + "/hidden_fraction"] = (r["hidden_fraction"], (ABS, 0.02))
        out[key + "/step_reduction"] = (r["step_reduction"], (ABS, 0.02))
        out[key + "/proj_skip_fraction"] = (
            r["proj_skip_fraction"], (ABS, 0.02))
    for r in blob.get("layer_rows", []):
        # layer-program step: occupancy-map (H, 8, n_l_blocks) counts
        # gated exactly, schedule ratios with float tolerance, the sim
        # twin's binary-phase prediction pinned sub-block-exact. The
        # `off` rows are the sequential oracle baseline — wall clock
        # only, never gated.
        if r["overlap"] == "off":
            continue
        key = _row_key("layer", r, ("config", "overlap", "sparse"))
        for ph in ("q", "k", "v", "qkt", "qktv", "wo", "up", "down"):
            out[key + f"/executed_{ph}"] = (r[f"executed_{ph}"], (EXACT,))
        for f in ("executed_steps", "possible_steps", "pipeline_iters",
                  "sim_binary_exact"):
            out[key + f"/{f}"] = (r[f], (EXACT,))
        for f in ("hidden_fraction", "qkt_hidden_fraction",
                  "qktv_hidden_fraction", "step_reduction",
                  "sim_binary_agreement"):
            out[key + f"/{f}"] = (r[f], (ABS, 0.02))
    # derived aggregates (max/mean over the sweep, auto-win counts) are
    # deliberately NOT gated: they change with the sweep size, so a full
    # run would spuriously drift vs a smoke baseline — the per-row keys
    # above carry the same information robustly.
    return out


def extract_quant(blob):
    """Quantized datapath: footprint compression (byte-counted, tight
    tolerance) and PTQ logit fidelity (spike-flip dominated, loose)."""
    out = {}
    fp = blob.get("footprint", {})
    for dtype in ("int8", "int4"):
        if dtype in fp:
            out[f"footprint/{dtype}/compression"] = (
                fp[dtype]["compression"], (REL, 0.005))
            out[f"footprint/{dtype}/total_compression"] = (
                fp[dtype]["total_compression"], (REL, 0.005))
    d = blob.get("derived", {})
    for arch, mae in d.get("int8_logit_mae_rel", {}).items():
        out[f"derived/int8_logit_mae_rel/{arch}"] = (mae, (ABS, 0.1))
    return out


def extract_serve(blob):
    """Serve orchestrator: chunked-prefill wave reduction per arch (a
    scheduler-counted quantity, not a timing)."""
    out = {}
    d = blob.get("derived", {})
    for arch, red in d.get("wave_reduction_chunked_vs_1", {}).items():
        out[f"derived/wave_reduction_chunked_vs_1/{arch}"] = (
            red, (ABS, 0.1))
    return out


SPECS = {
    "dual_engine_bench.json": extract_dual_engine,
    "quant_bench.json": extract_quant,
    "serve_bench.json": extract_serve,
}

# every key family (first path segment) an extractor can emit. A
# committed baseline key outside its artifact's registry means the
# bench stopped emitting that family entirely (renamed or removed):
# fail loud with the family named, instead of one generic "vanished"
# line per key, so the fix (regenerate baselines or restore the bench)
# is obvious.
KNOWN_PREFIXES = {
    "dual_engine_bench.json": ("linear", "sparse_path", "fused", "layer"),
    "quant_bench.json": ("footprint", "derived"),
    "serve_bench.json": ("derived",),
}

# acceptance floors checked against the *fresh* artifact (and at
# --update-baselines time), independent of the committed baseline — a
# baseline regeneration must never ratify a value below the floor. The
# layer floor pins the PR's claim: the whole-layer program's measured
# binary-hidden fraction on the token config strictly exceeds the
# SSA-only bundle's 0.3971 (fused_rows, spikingformer-lm).
FLOORS = {
    "dual_engine_bench.json": (
        ("layer/spikingformer-lm/fused/tile/hidden_fraction", 0.3971),
        ("layer/spikingformer-lm/pipeline/tile/hidden_fraction", 0.3971),
    ),
}


def _within(fresh, base, tol):
    if tol[0] == EXACT:
        return fresh == base
    if not isinstance(fresh, (int, float)) or isinstance(fresh, bool):
        return fresh == base
    if tol[0] == ABS:
        return abs(fresh - base) <= tol[1]
    return abs(fresh - base) <= tol[1] * max(abs(base), 1e-9)


def check(artifacts_dir: str, baselines_dir: str, update: bool) -> int:
    failures, checked = [], 0
    if update:
        # validate the whole artifact set BEFORE writing anything: a
        # partial update would leave a mixed fresh/stale baselines dir
        missing = [n for n in SPECS
                   if not os.path.exists(os.path.join(artifacts_dir, n))]
        if missing:
            for n in missing:
                print(f"  FAIL {n}: artifact missing in {artifacts_dir}")
            print("no baselines written — run the smoke benches for the "
                  "missing artifacts first.")
            return 1
    for name, extract in SPECS.items():
        apath = os.path.join(artifacts_dir, name)
        bpath = os.path.join(baselines_dir, name)
        if not os.path.exists(apath):
            failures.append(f"{name}: artifact missing at {apath} "
                            f"(run the smoke benches first)")
            continue
        try:
            with open(apath) as f:
                pairs = extract(json.load(f))
        except (KeyError, TypeError, AttributeError,
                json.JSONDecodeError) as e:
            failures.append(f"{name}: stale or malformed artifact "
                            f"({type(e).__name__}: {e}) — regenerate "
                            f"with the smoke benches")
            continue
        fresh = {k: v for k, (v, _) in pairs.items()}
        tols = {k: t for k, (_, t) in pairs.items()}
        floor_fails = []
        for key, floor in FLOORS.get(name, ()):
            if key not in fresh:
                floor_fails.append(f"{name}:{key}: floor metric missing "
                                   f"(must be strictly above {floor})")
            elif not fresh[key] > floor:
                floor_fails.append(f"{name}:{key}: {fresh[key]} is not "
                                   f"strictly above the floor {floor}")
        failures.extend(floor_fails)
        checked += len(FLOORS.get(name, ()))
        if update:
            if floor_fails:
                continue      # never ratify a below-floor artifact
            os.makedirs(baselines_dir, exist_ok=True)
            with open(bpath, "w") as f:
                json.dump(fresh, f, indent=1, sort_keys=True)
            print(f"updated {bpath} ({len(fresh)} metrics)")
            continue
        if not os.path.exists(bpath):
            failures.append(f"{name}: no committed baseline at {bpath} "
                            f"(run with --update-baselines and commit)")
            continue
        with open(bpath) as f:
            base = json.load(f)
        known = KNOWN_PREFIXES.get(name)
        if known is not None:
            for fam in sorted({k.split("/", 1)[0] for k in base}
                              - set(known)):
                n = sum(1 for k in base if k.split("/", 1)[0] == fam)
                failures.append(
                    f"{name}: stale baseline family '{fam}' ({n} keys) "
                    f"— no bench emits this prefix anymore; regenerate "
                    f"baselines (--update-baselines) and commit")
        for key, bval in sorted(base.items()):
            if known is not None and key.split("/", 1)[0] not in known:
                continue      # reported above as a stale family
            checked += 1
            if key not in fresh:
                failures.append(f"{name}:{key}: metric vanished "
                                f"(baseline {bval})")
                continue
            tol = tols.get(key, (EXACT,))
            if not _within(fresh[key], bval, tol):
                failures.append(
                    f"{name}:{key}: {fresh[key]} vs baseline {bval} "
                    f"(tol {tol})")
    if update:
        if failures:  # e.g. a malformed artifact surfaced mid-update
            for f in failures:
                print(f"  FAIL {f}")
            print("baselines NOT fully updated — fix the artifacts "
                  "above and rerun.")
            return 1
        return 0
    if failures:
        print(f"BENCH REGRESSION: {len(failures)} of {checked} gated "
              f"metrics drifted:")
        for f in failures:
            print(f"  FAIL {f}")
        print("If the drift is intentional, regenerate baselines "
              "(--update-baselines after the smoke benches) and commit.")
        return 1
    print(f"bench regression gate: {checked} metrics within tolerance "
          f"across {len(SPECS)} artifacts")
    return 0


def main():
    ap = argparse.ArgumentParser()
    here = os.path.dirname(os.path.abspath(__file__))
    ap.add_argument("--artifacts", default=os.path.join(here, "..",
                                                        "artifacts"))
    ap.add_argument("--baselines", default=os.path.join(here, "baselines"))
    ap.add_argument("--update-baselines", action="store_true")
    args = ap.parse_args()
    sys.exit(check(args.artifacts, args.baselines, args.update_baselines))


if __name__ == "__main__":
    main()
