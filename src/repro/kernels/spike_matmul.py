"""Block-sparse spike matmul — the sparse engine's MXU adaptation.

FireFly-T's sparse engine skips zero spikes at bit granularity with
multi-lane decoders + out-of-order workers. The MXU's profitable skip
granularity is a whole VMEM tile (DESIGN.md §3): this kernel computes
``y = s @ w`` (spikes x weights) with a per-(block_m x block_k) *occupancy
bitmap* computed upfront (the block-granular analogue of the decoder's
bitmap), and skips the inner dot entirely for all-zero spike blocks via
``@pl.when`` — no weight fetch, no MACs, matching Observation 1 (sparsity
is uniform across the spatial-temporal grid, so whole-tile skips fire
often at >=75% sparsity only when channel-blocks are coherently sparse;
the occupancy reduction itself is the multi-lane decode).

Grid: (nM, nN, nK), K innermost; fp32 accumulator in the revisited output
block. The occupancy map is a tiny (nM, nK) int32 array staged per-step.
A fused bias lands on the last K step, after the final accumulation, so
the dense reference (fp32 dot, then bias) is reproduced term-for-term.

Shapes that don't divide the block sizes are zero-padded: padded K
columns contribute exact fp32 zeros (and all-zero padded blocks are
skipped by occupancy anyway), padded M rows / N columns are sliced off.
``spike_matmul_batched`` folds arbitrary leading ``(T, B, ...)`` dims
into M — the layout every model activation ``(T, B, L, D)`` arrives in.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.bitpack import pad_to_multiple


def _kernel(occ_ref, s_ref, w_ref, o_ref):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(occ_ref[0, 0] > 0)
    def _compute():
        s = s_ref[...].astype(jnp.float32)
        w = w_ref[...].astype(jnp.float32)
        o_ref[...] += jax.lax.dot_general(
            s, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)


def _kernel_bias(occ_ref, s_ref, w_ref, b_ref, o_ref, *, nk):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(occ_ref[0, 0] > 0)
    def _compute():
        s = s_ref[...].astype(jnp.float32)
        w = w_ref[...].astype(jnp.float32)
        o_ref[...] += jax.lax.dot_general(
            s, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _bias():
        o_ref[...] += b_ref[...].astype(jnp.float32)


def _qkernel(occ_ref, s_ref, w_ref, scale_ref, o_ref, acc_ref, *, nk):
    """Quantized-weight body: spike {0,1} rows x int8 weight rows with an
    **int32 accumulator** in VMEM scratch (the MXU's native int8 x int8 ->
    int32 form, the TPU analogue of FireFly-T's int8 DSP datapath),
    per-output-channel fp32 scale applied in the epilogue on the last K
    step. The occupancy skip is unchanged: a dark spike block fetches no
    weights and adds no MACs, whatever the weight dtype."""
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(occ_ref[0, 0] > 0)
    def _compute():
        acc_ref[...] += jax.lax.dot_general(
            s_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)

    @pl.when(ki == nk - 1)
    def _epilogue():
        o_ref[...] = acc_ref[...].astype(jnp.float32) * \
            scale_ref[...].astype(jnp.float32)


def _qkernel_bias(occ_ref, s_ref, w_ref, scale_ref, b_ref, o_ref, acc_ref,
                  *, nk):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(occ_ref[0, 0] > 0)
    def _compute():
        acc_ref[...] += jax.lax.dot_general(
            s_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)

    @pl.when(ki == nk - 1)
    def _epilogue():
        o_ref[...] = acc_ref[...].astype(jnp.float32) * \
            scale_ref[...].astype(jnp.float32) + \
            b_ref[...].astype(jnp.float32)


def block_occupancy(s: jax.Array, block_m: int, block_k: int) -> jax.Array:
    """(M, K) spikes -> (nM, nK) int32 any-nonzero per block."""
    m, k = s.shape
    occ = (s != 0).reshape(m // block_m, block_m, k // block_k,
                           block_k).any(axis=(1, 3))
    return occ.astype(jnp.int32)


def spike_matmul(s: jax.Array, w: jax.Array, *,
                 bias: Optional[jax.Array] = None,
                 block_m: int = 128, block_n: int = 128, block_k: int = 128,
                 occupancy: Optional[jax.Array] = None,
                 out_dtype=None,
                 interpret: Optional[bool] = None) -> jax.Array:
    """y = s @ w (+ bias); s: (M, K) {0,1} spikes, w: (K, N) weights ->
    (M, N) fp32 cast to ``out_dtype`` (default w.dtype; pass jnp.float32
    to keep the raw accumulator — the engine does, so mixed weight/
    activation dtypes round once, not twice). Zero spike blocks are
    skipped; shapes that don't divide the blocks are zero-padded and
    sliced back."""
    m, k = s.shape
    k2, n = w.shape
    assert k == k2
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    sp = pad_to_multiple(pad_to_multiple(s, 0, block_m), 1, block_k)
    wp = pad_to_multiple(pad_to_multiple(w, 0, block_k), 1, block_n)
    mp, kp = sp.shape
    np_ = wp.shape[1]
    occ = block_occupancy(sp, block_m, block_k) if occupancy is None \
        else occupancy

    grid = (mp // block_m, np_ // block_n, kp // block_k)
    in_specs = [
        pl.BlockSpec((1, 1), lambda mi, ni, ki: (mi, ki)),
        pl.BlockSpec((block_m, block_k), lambda mi, ni, ki: (mi, ki)),
        pl.BlockSpec((block_k, block_n), lambda mi, ni, ki: (ki, ni)),
    ]
    operands = [occ, sp, wp]
    if bias is None:
        kernel = _kernel
    else:
        kernel = functools.partial(_kernel_bias, nk=grid[2])
        in_specs.append(pl.BlockSpec((1, block_n),
                                     lambda mi, ni, ki: (0, ni)))
        operands.append(pad_to_multiple(bias.reshape(1, n), 1, block_n))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n),
                               lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(*operands)
    return out[:m, :n].astype(w.dtype if out_dtype is None else out_dtype)


def quant_spike_matmul(s: jax.Array, qw: jax.Array, scale: jax.Array, *,
                       bias: Optional[jax.Array] = None,
                       block_m: int = 128, block_n: int = 128,
                       block_k: int = 128,
                       occupancy: Optional[jax.Array] = None,
                       counts: bool = False,
                       interpret: Optional[bool] = None) -> jax.Array:
    """y = (s @ qw) * scale (+ bias); s: (M, K) {0,1} spikes, qw: (K, N)
    int8 weight codes, scale: (N,) fp32 per-output-channel -> (M, N) fp32.

    The integer half of the dual-side compression: spikes enter the MXU as
    int8 {0,1}, weights as int8 codes, partial sums accumulate in int32
    VMEM scratch (exact — no fp rounding inside the reduction), and the
    per-channel scale lands once in the epilogue. Under dyadic scales the
    result is bitwise equal to the fp32 reference on dequantized weights
    (DESIGN.md §8). Occupancy skip, padding, and tiling mirror
    :func:`spike_matmul`.

    ``counts=True`` declares the left operand as binary-attention integer
    counts (values up to L, not {0,1}): it rides int32 lanes instead of
    int8 — an int8 cast would silently wrap counts >= 128. The weight
    side (the bandwidth that quantization buys back) stays int8 either
    way.
    """
    m, k = s.shape
    k2, n = qw.shape
    assert k == k2, f"spikes K={k} vs weight K={k2}"
    assert qw.dtype == jnp.int8, f"quant kernel wants int8 codes, got " \
        f"{qw.dtype} (unpack int4 nibbles first)"
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    sp = pad_to_multiple(pad_to_multiple(s, 0, block_m), 1, block_k)
    wp = pad_to_multiple(pad_to_multiple(qw, 0, block_k), 1, block_n)
    mp, kp = sp.shape
    np_ = wp.shape[1]
    occ = block_occupancy(sp, block_m, block_k) if occupancy is None \
        else occupancy
    s_int = sp.astype(jnp.int32 if counts else jnp.int8)

    grid = (mp // block_m, np_ // block_n, kp // block_k)
    in_specs = [
        pl.BlockSpec((1, 1), lambda mi, ni, ki: (mi, ki)),
        pl.BlockSpec((block_m, block_k), lambda mi, ni, ki: (mi, ki)),
        pl.BlockSpec((block_k, block_n), lambda mi, ni, ki: (ki, ni)),
        pl.BlockSpec((1, block_n), lambda mi, ni, ki: (0, ni)),
    ]
    operands = [occ, s_int, wp,
                pad_to_multiple(scale.reshape(1, n).astype(jnp.float32),
                                1, block_n)]
    if bias is None:
        kernel = functools.partial(_qkernel, nk=grid[2])
    else:
        kernel = functools.partial(_qkernel_bias, nk=grid[2])
        in_specs.append(pl.BlockSpec((1, block_n),
                                     lambda mi, ni, ki: (0, ni)))
        operands.append(pad_to_multiple(
            bias.reshape(1, n).astype(jnp.float32), 1, block_n))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n),
                               lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        interpret=interpret,
    )(*operands)
    return out[:m, :n]


def spike_matmul_batched(s: jax.Array, w: jax.Array, *,
                         bias: Optional[jax.Array] = None,
                         block_m: int = 128, block_n: int = 128,
                         block_k: int = 128,
                         interpret: Optional[bool] = None) -> jax.Array:
    """y = s @ w (+ bias) over arbitrary leading dims.

    s: (T, B, ..., K) spikes; the leading dims fold into the kernel's M —
    the spatial-temporal grid is one flat stream of rows to the sparse
    engine, so whole-tile skips fire across time steps and batch entries
    alike. Returns (T, B, ..., N) in w.dtype.
    """
    lead = s.shape[:-1]
    y = spike_matmul(s.reshape(-1, s.shape[-1]), w, bias=bias,
                     block_m=block_m, block_n=block_n, block_k=block_k,
                     interpret=interpret)
    return y.reshape(*lead, w.shape[1])
