"""Fault-tolerant training driver.

Runs on whatever devices exist (CPU: 1-device mesh; TPU: the production
mesh) with: pjit'd train step, deterministic synthetic data, async
checkpointing + auto-restore, failure injection + supervisor restarts,
straggler monitoring, optional int8 gradient compression.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch spikingformer-4-256 \
      --smoke --steps 50
  PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-3-4b \
      --smoke --steps 100 --batch 8 --seq 128 --inject-failure-at 30
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import ALL_ARCHS, get_config
from repro.data import DataConfig, make_pipeline
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.models import registry
from repro.models.moe import use_ep_mesh
from repro.optim import adamw, compress_state_init, warmup_cosine
from repro.runtime import (FailureInjector, StragglerMonitor, TrainSupervisor,
                           SimulatedFailure)


def make_batch_fn(cfg, batch_size: int, seq_len: int):
    if cfg.family in ("spikingformer", "cifarnet"):
        data = make_pipeline(DataConfig(
            kind="images", global_batch=batch_size,
            img_size=cfg.vision.img_size, channels=cfg.vision.in_channels,
            num_classes=cfg.vocab_size))
        return data.batch_at
    data = make_pipeline(DataConfig(kind="lm", global_batch=batch_size,
                                    seq_len=seq_len,
                                    vocab_size=cfg.vocab_size))
    lm_batch = data.batch_at

    if cfg.family == "vlm":
        n, e = cfg.frontend.num_embeds, cfg.frontend.embed_dim

        def fn(step):
            b = lm_batch(step)
            rng = np.random.default_rng(step)
            b["patch_embeds"] = rng.normal(
                0, 0.02, (batch_size, n, e)).astype(np.float32)
            return b
        return fn
    if cfg.family == "encdec":
        def fn(step):
            b = lm_batch(step)
            rng = np.random.default_rng(step)
            b["audio_embeds"] = rng.normal(
                0, 0.02, (batch_size, cfg.encoder_seq,
                          cfg.d_model)).astype(np.float32)
            return b
        return fn
    return lm_batch


def train(arch: str, smoke: bool, total_steps: int, batch: int, seq: int,
          lr: float, ckpt_dir: Optional[str], ckpt_every: int,
          inject_failure_at: Optional[int], compress: bool,
          log_every: int = 10, seed: int = 0, qat: Optional[str] = None):
    cfg = get_config(arch, smoke=smoke)
    stateful = cfg.family in ("spikingformer", "cifarnet")
    mesh = make_host_mesh()
    opt = adamw(warmup_cosine(lr, max(1, total_steps // 20), total_steps))
    batch_fn = make_batch_fn(cfg, batch, seq)
    train_step = steps_lib.build_train_step(cfg, opt, compress=compress,
                                            qat=qat)
    jitted = jax.jit(train_step, donate_argnums=(0, 1))

    params = registry.init(cfg, jax.random.PRNGKey(seed))
    opt_state = opt.init(params)
    if compress:
        opt_state["compress_err"] = compress_state_init(params)
    model_state = registry.init_state(cfg)
    n_params = sum(np.prod(l.shape) for l in
                   jax.tree_util.tree_leaves(params))
    print(f"[train] {cfg.name} ({'smoke' if smoke else 'full'}): "
          f"{n_params/1e6:.2f}M params, {total_steps} steps, "
          f"batch={batch} seq={seq}")

    cm = CheckpointManager(ckpt_dir) if ckpt_dir else None
    injector = FailureInjector(failure_steps=[inject_failure_at]
                               if inject_failure_at else [])
    monitor = StragglerMonitor(
        on_straggler=lambda r: print(
            f"[straggler] step {r.step}: {r.seconds*1e3:.0f} ms"))
    supervisor = TrainSupervisor(max_restarts=3)
    losses = []

    def run_segment(start_step: int) -> int:
        nonlocal params, opt_state, model_state
        if cm is not None and cm.latest_step() is not None:
            tmpl = {"params": params, "opt": opt_state}
            if stateful:
                tmpl["model_state"] = model_state
            tree, ck_step, _ = cm.restore(tmpl)
            params, opt_state = tree["params"], tree["opt"]
            if stateful:
                model_state = tree["model_state"]
            start_step = ck_step
            print(f"[train] restored checkpoint @ step {ck_step}")
        step_arr = jnp.asarray(start_step, jnp.int32)
        step = start_step
        while step < total_steps:
            injector.maybe_fail(step)
            b = {k: jnp.asarray(v) for k, v in batch_fn(step).items()}
            t0 = time.time()
            if stateful:
                params, opt_state, step_arr, metrics, model_state = jitted(
                    params, opt_state, step_arr, b, model_state)
            else:
                params, opt_state, step_arr, metrics = jitted(
                    params, opt_state, step_arr, b)
            loss = float(metrics["loss"])
            monitor.observe(step, time.time() - t0)
            losses.append(loss)
            if step % log_every == 0 or step == total_steps - 1:
                extra = f" fire={float(metrics['fire_rate']):.3f}" \
                    if "fire_rate" in metrics else ""
                print(f"[train] step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f}{extra}")
            step += 1
            if cm is not None and step % ckpt_every == 0:
                tree = {"params": params, "opt": opt_state}
                if stateful:
                    tree["model_state"] = model_state
                cm.save(step, tree)
        if cm is not None:
            tree = {"params": params, "opt": opt_state}
            if stateful:
                tree["model_state"] = model_state
            cm.save(total_steps, tree, blocking=True)
        return step

    final = supervisor.run(run_segment, 0, total_steps)
    if supervisor.restarts:
        print(f"[train] survived {len(supervisor.restarts)} restart(s): "
              f"{supervisor.restarts}")
    if monitor.straggler_steps:
        print(f"[train] straggler steps flagged: {monitor.straggler_steps}")
    print(f"[train] done @ step {final}; first loss {losses[0]:.4f} "
          f"last loss {losses[-1]:.4f}")
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ALL_ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--inject-failure-at", type=int, default=None)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--qat", default=None, choices=["int8", "int4"],
                    help="quantization-aware training: the loss sees "
                         "fake-quantized linears (STE grads to fp32 "
                         "masters; repro.quant.qat)")
    args = ap.parse_args()
    train(args.arch, args.smoke, args.steps, args.batch, args.seq, args.lr,
          args.ckpt_dir, args.ckpt_every, args.inject_failure_at,
          args.compress_grads, qat=args.qat)


if __name__ == "__main__":
    main()
