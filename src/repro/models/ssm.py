"""Mamba-style selective SSM layer (used by hymba's parallel mamba heads).

Mamba-1 selective scan: input-dependent (Δ, B, C) with diagonal A, causal
depthwise conv front, gated output. State is ``(B, d_inner, d_state)``
(hymba: d_state=16). Sequence recurrence via ``lax.scan``; the chunked
parallel form is a §Perf item.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from . import nn


def d_inner_of(cfg: ModelConfig) -> int:
    return cfg.ssm.expand * cfg.d_model


def dt_rank_of(cfg: ModelConfig) -> int:
    return cfg.ssm.dt_rank or -(-cfg.d_model // 16)


def ssm_init(key, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    s = cfg.ssm
    d = cfg.d_model
    di = d_inner_of(cfg)
    dr = dt_rank_of(cfg)
    ks = jax.random.split(key, 6)
    a_init = jnp.tile(jnp.arange(1, s.d_state + 1, dtype=jnp.float32)[None],
                      (di, 1))
    return {
        "in_proj": nn.linear_init(ks[0], d, 2 * di, dtype=dt),
        "conv_w": nn.normal(ks[1], (s.d_conv, di), 1.0 / math.sqrt(s.d_conv),
                            dt),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": nn.linear_init(ks[2], di, dr + 2 * s.d_state, dtype=dt),
        "dt_proj": nn.linear_init(ks[3], dr, di, bias=True, dtype=dt),
        "A_log": jnp.log(a_init),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": nn.linear_init(ks[4], di, d, dtype=dt),
    }


def _ssm_params(p, x_c, cfg: ModelConfig):
    """x_c: (B, S, di) post-conv -> (dt (B,S,di), Bm (B,S,N), Cm (B,S,N))."""
    s = cfg.ssm
    dr = dt_rank_of(cfg)
    dbc = nn.linear(p["x_proj"], x_c)
    dt_r, bm, cm = jnp.split(dbc, [dr, dr + s.d_state], axis=-1)
    dt = jax.nn.softplus(nn.linear(p["dt_proj"], dt_r).astype(jnp.float32))
    return dt, bm.astype(jnp.float32), cm.astype(jnp.float32)


def ssm_forward(p, x, cfg: ModelConfig, state=None, conv_state=None):
    """Full-sequence selective scan. x: (B, S, D).

    Returns (y (B, S, D), final ssm state, final conv state).
    """
    s = cfg.ssm
    b, slen, _ = x.shape
    di = d_inner_of(cfg)
    xz = nn.linear(p["in_proj"], x)
    x_in, z = jnp.split(xz, 2, axis=-1)
    if conv_state is not None:
        x_pad = jnp.concatenate([conv_state, x_in], axis=1)
    else:
        x_pad = jnp.pad(x_in, ((0, 0), (s.d_conv - 1, 0), (0, 0)))
    # causal depthwise conv over the padded buffer
    out = jnp.zeros((b, slen, di), jnp.float32)
    for i in range(s.d_conv):
        out = out + x_pad[:, i:i + slen].astype(jnp.float32) * \
            p["conv_w"][i].astype(jnp.float32)
    x_c = jax.nn.silu(out + p["conv_b"].astype(jnp.float32)).astype(x.dtype)

    dt, bm, cm = _ssm_params(p, x_c, cfg)
    a = -jnp.exp(p["A_log"])                                   # (di, N)
    h0 = jnp.zeros((b, di, s.d_state), jnp.float32) if state is None else state

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp                              # (B,di),(B,di),(B,N),(B,N)
        da = jnp.exp(dt_t[..., None] * a[None])                # (B,di,N)
        h = da * h + dt_t[..., None] * b_t[:, None, :] * x_t[..., None]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (jnp.moveaxis(x_c.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt, 1, 0), jnp.moveaxis(bm, 1, 0),
          jnp.moveaxis(cm, 1, 0))
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + x_c.astype(jnp.float32) * p["D"]
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    new_conv_state = x_pad[:, -(s.d_conv - 1):] if s.d_conv > 1 else \
        jnp.zeros((b, 0, di), x.dtype)
    return nn.linear(p["out_proj"], y), h_final, new_conv_state


def ssm_decode(p, x, cfg: ModelConfig, state, conv_state):
    """One-token decode. x: (B, 1, D); state (B, di, N); conv (B, K-1, di)."""
    y, h, conv = ssm_forward(p, x, cfg, state=state, conv_state=conv_state)
    return y, h, conv


def zero_states(cfg: ModelConfig, n_layers: int, b: int):
    s = cfg.ssm
    di = d_inner_of(cfg)
    return {
        "ssm": jnp.zeros((n_layers, b, di, s.d_state), jnp.float32),
        "conv": jnp.zeros((n_layers, b, s.d_conv - 1, di),
                          jnp.dtype(cfg.dtype)),
    }
