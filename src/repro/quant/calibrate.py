"""Post-training-quantization range calibration over a batch.

Symmetric per-output-channel quantization has one free knob per layer: the
clip point. ``amax`` clipping (clip_ratio = 1.0) spends int8 codes on the
single largest weight in a channel; tighter clips trade a little clipping
error on outliers for finer resolution everywhere else. For the *analog*
(non-spike) layers of a spiking LM — the Q/K/V/O projections and MLP
matmuls whose inputs are membrane currents, plus the LM head — the right
clip depends on how weight error propagates through LIF thresholds and
binary attention, which no weight-space metric sees. So we calibrate the
whole model at once: sweep a small clip-ratio grid, run the quantized
forward on a calibration batch, and keep the ratio whose logits sit
closest to the fp32 reference (mean |Δ|). One global ratio, measured
end to end — the grid is tiny because per-channel scales already absorb
inter-channel spread.

``calibrate`` returns the winning quantized tree plus a report
(per-candidate logit MAE, the fp32 reference scale) the benchmarks emit
into ``artifacts/quant_bench.json``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax.numpy as jnp

from .quantize import quantize_tree

DEFAULT_RATIOS = (1.0, 0.95, 0.9, 0.8)


def logit_delta(ref: Any, out: Any) -> Dict[str, float]:
    """Calibration distance between two logit tensors: mean |Δ| plus the
    normalized form (mae / std(ref)) that is comparable across configs."""
    ref32 = jnp.asarray(ref, jnp.float32)
    out32 = jnp.asarray(out, jnp.float32)
    mae = float(jnp.abs(out32 - ref32).mean())
    std = float(ref32.std())
    return {"logit_mae": mae,
            "logit_mae_rel": mae / max(std, 1e-12),
            "ref_std": std,
            "argmax_agree": float(
                (out32.argmax(-1) == ref32.argmax(-1)).mean())}


def calibrate(cfg, params, batch, dtype: str = "int8", *,
              ratios: Sequence[float] = DEFAULT_RATIOS,
              state=None) -> Tuple[Any, Dict[str, Any]]:
    """PTQ calibration of a model's linears over one batch.

    Runs the fp32 reference forward once, then one quantized forward per
    clip-ratio candidate, and returns ``(best quantized tree, report)``.
    ``state`` threads BatchNorm running stats for the stateful families
    (spikingformer / cifarnet).
    """
    from repro.models import registry  # lazy: quant stays model-agnostic

    kw = {} if state is None else {"state": state}
    ref, _ = registry.forward(params, cfg, batch, train=False, **kw)
    best = None
    candidates = []
    for r in ratios:
        qtree = quantize_tree(params, dtype, clip_ratio=r)
        out, _ = registry.forward(qtree, cfg, batch, train=False, **kw)
        d = logit_delta(ref, out)
        candidates.append({"clip_ratio": r, **d})
        if best is None or d["logit_mae"] < best[1]["logit_mae"]:
            best = (qtree, {"clip_ratio": r, **d})
    report = {"dtype": dtype, "chosen": best[1], "candidates": candidates}
    return best[0], report
