"""rwkv6-3b [ssm]: 32L d_model=2560 (attention-free) d_ff=8960 vocab=65536
— RWKV-6 "Finch", data-dependent decay [arXiv:2404.05892; hf].

FireFly-T binary engine inapplicable (no QK^T) — DESIGN.md §5.
"""
from .base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="rwkv",
    num_layers=32, d_model=2560, num_heads=40, num_kv_heads=40, head_dim=64,
    d_ff=8960, vocab_size=65536,
    rwkv=RWKVConfig(head_size=64, lora_mix=32, lora_decay=64,
                    wkv_chunk=32),  # chunk-parallel WKV (§Perf R1)
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512, dtype="float32", remat=False,
    rwkv=RWKVConfig(head_size=16, lora_mix=8, lora_decay=8))
