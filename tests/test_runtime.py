"""Fault tolerance, straggler mitigation, elastic restore, end-to-end
training integration (loss decreases; failure-restart resumes)."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import train
from repro.parallel.sharding import fit_spec_to_shape
from repro.runtime import (FailureInjector, SimulatedFailure,
                           StragglerMonitor, TrainSupervisor,
                           elastic_restore_plan)


def test_failure_injector_deterministic():
    inj = FailureInjector(failure_steps=[3])
    inj.maybe_fail(2)
    with pytest.raises(SimulatedFailure):
        inj.maybe_fail(3)
    inj.maybe_fail(3)  # only fails once
    assert inj.injected == [3]


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(threshold=2.0)
    for i in range(10):
        mon.observe(i, 0.1)
    rec = mon.observe(10, 0.5)
    assert rec.flagged and mon.straggler_steps == [10]
    # EWMA not poisoned by the outlier
    assert mon.ewma == pytest.approx(0.1, rel=0.05)


def test_supervisor_restart_budget():
    calls = []

    def seg(start):
        calls.append(start)
        if len(calls) < 3:
            raise SimulatedFailure("boom")
        return 10

    sup = TrainSupervisor(max_restarts=3)
    assert sup.run(seg, 0, 10) == 10
    assert len(sup.restarts) == 2

    sup2 = TrainSupervisor(max_restarts=1)

    def always_fail(start):
        raise SimulatedFailure("boom")
    with pytest.raises(RuntimeError):
        sup2.run(always_fail, 0, 10)


def test_elastic_restore_plan():
    plan = elastic_restore_plan({"data": 16, "model": 16},
                                {"pod": 2, "data": 16, "model": 16}, 256)
    assert plan["dp_degree"] == 32 and plan["per_shard_batch"] == 8
    with pytest.raises(ValueError):
        elastic_restore_plan({"data": 16}, {"data": 7, "pod": 1}, 256)


def test_fit_spec_drops_nondividing_axes():
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("model",))  # 1 device: everything divides
    s = fit_spec_to_shape(P("model", "data"), (25, 64), mesh)
    assert s == P("model", None) or s == P("model")  # 'data' not in mesh


@pytest.mark.slow
def test_training_loss_decreases_lm():
    losses = train("h2o-danube-3-4b", smoke=True, total_steps=30, batch=8,
                   seq=64, lr=3e-3, ckpt_dir=None, ckpt_every=100,
                   inject_failure_at=None, compress=False)
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.3, (first, last)


@pytest.mark.slow
def test_training_survives_failure_and_resumes():
    with tempfile.TemporaryDirectory() as d:
        losses = train("cifarnet", smoke=True, total_steps=14, batch=8,
                       seq=32, lr=1e-3, ckpt_dir=d, ckpt_every=5,
                       inject_failure_at=7, compress=False)
    # 14 nominal steps + replayed steps 5..6 after restore
    assert len(losses) >= 14
    assert all(np.isfinite(losses))


@pytest.mark.slow
def test_training_with_grad_compression_close_to_uncompressed():
    kw = dict(smoke=True, total_steps=25, batch=8, seq=64, lr=3e-3,
              ckpt_dir=None, ckpt_every=100, inject_failure_at=None)
    base = train("h2o-danube-3-4b", compress=False, **kw)
    comp = train("h2o-danube-3-4b", compress=True, **kw)
    assert abs(np.mean(base[-5:]) - np.mean(comp[-5:])) < 0.25
