"""Dual-engine sweep: both halves of the overlay.

Sparse engine (``rows``): dense XLA dot vs occupancy-skipping sparse
kernel. For each (sparsity, block, shape) point this times
``spike_linear``'s two dispatch targets on the same spike tensor and
records

  * dense_us / sparse_us — wall time per call (median of reps). On CPU
    the kernel runs in Pallas *interpret* mode, so the wall-clock ratio
    measures the lowered-lax emulation, not MXU tiles — the number that
    transfers to TPU is ``modeled_speedup``;
  * skip_fraction — fraction of (block_m x block_k) spike tiles whose
    occupancy bit is 0 (the sparse engine skips them: no weight fetch,
    no MACs);
  * modeled_speedup — 1 / (1 - skip_fraction), the MAC-count reduction
    the occupancy map guarantees on any backend.

Spikes are generated with *coherent* tile sparsity (Observation 1: spike
sparsity is uniform across the spatial-temporal grid, so channel blocks
go dark together): ``sparsity`` is the fraction of dead tiles; live
tiles fire at 25% density. That is the regime where whole-tile skips
pay; i.i.d. Bernoulli sparsity at the same rate almost never yields an
empty 128x128 tile and is reported by the bench as skip_fraction ~ 0.

Sparse datapaths (``sparse_path_rows``): tile vs decoded
(``EngineConfig.sparse``, DESIGN.md §9) on *fine-grained / ragged*
spike patterns — the regime where whole-tile skips never fire
(``skip_fraction ~ 0``) but per-row occupancy is low, so the
gather-compacted kernel's pow2 bucket schedule still cuts MACs. Each
row records both paths' wall time, the tile skip fraction, the decoded
schedule's MAC fraction (executed / total c_block-steps, scaled by the
compacted width), and the cross-validation of
``sim/balance_sim.predicted_schedule`` (Binomial occupancies from the
generator's density model) against the measured tensor schedule
(``kernels/spike_decode.build_schedule``) — ``sched_agreement`` is
predicted/measured executed steps. ``auto_choice`` is what
``sparse='auto'`` would pick from the concrete histogram.

Binary engine (``attention_rows``): the three SSA execution targets of
``core.engine.resolve_binary_mode`` — pure jnp, the fused MXU Pallas
kernel, the bit-packed popcount port — swept over L x d_head x causal on
identical spike tensors. All three are bit-identical (pinned by
tests/test_binary_engine.py); the sweep quantifies the *speed* gap the
dispatch rules encode (DESIGN.md §3: MXU dominates popcount on TPU). On
CPU the kernels run in interpret mode, so kernel wall-clock measures the
lowered-lax emulation — jnp_us is the transferable baseline there.

The measured medians also feed the overlap model: ``derived
['measured_overlap']`` runs ``core.dual_engine.measured_schedule`` on
(sparse_us, mxu_us) — the Fig. 5 latency-hiding fraction from measured
engine timings instead of the analytic MAC model.

Fused layer step (``fused_rows``): the ``overlap='fused'`` bundle
(``kernels/fused_ssa``) on the three spikingformer-shaped SSA
workloads. Each row feeds the kernel's per-head executed-step counts to
``core.dual_engine.fused_step_metrics``, so ``hidden_fraction`` here is
*measured* — derived from the dots the kernel actually ran (dark spike
slabs skipped, attention pipelined behind the next head's projections)
with exact per-dot MAC weights — not the analytic model. Those counts
are deterministic for the fixed PRNG inputs, so CI gates them
(``benchmarks/check_regression.py``); ``fused_us``/``sequential_us``
are interpret-mode wall clock on CPU and stay informative-only.

Layer-program step (``layer_rows``): the whole encoder layer — SSA
bundle + output projection + spiking MLP — as one engine step
(``kernels/fused_layer`` behind ``core.engine.layer_step``), swept over
``overlap in {off, fused, pipeline}`` x ``sparse in {tile, decoded}``
on the same three spikingformer workloads. Each row feeds the kernel's
``(H, 8, n_l_blocks)`` occupancy map to ``fused_step_metrics``:
``hidden_fraction`` here is the *binary-hidden* fraction — the share of
the binary engine's executed attention MACs that ride under sparse-
engine busy time in the measured schedule. The layer program's MLP
phases keep the sparse engine saturated past the SSA bundle's horizon,
which is exactly why it beats the bundle-only ``fused_rows`` number —
the CI floor on the token config (``check_regression.FLOORS``) pins
that claim. Rows also cross-validate ``sim/balance_sim
.binary_block_schedule`` (the numpy twin of the binary-phase occupancy
predicate) sub-block-exact against the measured counts.

Output: ``artifacts/dual_engine_bench.json`` in the benchmark harness's
``{"rows": [...], "attention_rows": [...], "sparse_path_rows": [...],
"fused_rows": [...], "layer_rows": [...], "derived": {...}}`` format
(also wired into ``benchmarks/run.py``, which re-emits the same file).

Usage: PYTHONPATH=src python benchmarks/dual_engine_bench.py [--fast]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

SHAPES = [(256, 128, 256), (512, 256, 256), (1024, 256, 512)]  # (M, K, N)
BLOCKS = [64, 128]
SPARSITIES = [0.5, 0.75, 0.9]
REPS = 5

# binary-engine sweep: (BH, L, d_head); 100 is deliberately non-divisible
# by the 128 attention blocks (exercises the kernels' zero-padding)
ATTN_SHAPES = [(8, 64, 32), (8, 100, 64), (8, 256, 64)]
ATTN_CAUSAL = [False, True]
ATTN_DENSITY = 0.25


def coherent_spikes(key, m, k, block, sparsity, density=0.25):
    """{0,1} (M, K) with ``sparsity`` fraction of (block x block) dead
    tiles; live tiles fire i.i.d. at ``density``."""
    k1, k2 = jax.random.split(key)
    nm, nk = -(-m // block), -(-k // block)
    live = jax.random.uniform(k1, (nm, nk)) >= sparsity
    tile_mask = jnp.repeat(jnp.repeat(live, block, 0), block, 1)[:m, :k]
    fire = jax.random.uniform(k2, (m, k)) < density
    return (tile_mask & fire).astype(jnp.float32)


def ragged_spikes(key, m, k, lo, hi):
    """{0,1} (M, K) with per-row i.i.d. firing at a log-uniform density
    in [lo, hi] — ragged occupancy, no tile coherence (the FireFly-S
    fine-grained regime the tile skip can't touch). Returns (spikes,
    per-row densities) so the bench can feed the density model to
    ``sim/balance_sim.predicted_schedule``."""
    k1, k2 = jax.random.split(key)
    logd = jax.random.uniform(k1, (m,), minval=jnp.log(lo),
                              maxval=jnp.log(hi))
    dens = jnp.exp(logd)
    s = (jax.random.uniform(k2, (m, k)) < dens[:, None])
    return s.astype(jnp.float32), dens


def fine_spikes(key, m, k, density):
    """{0,1} (M, K) i.i.d. Bernoulli — uniform fine-grained firing."""
    s = (jax.random.uniform(key, (m, k)) < density).astype(jnp.float32)
    return s, jnp.full((m,), density)


# sparse-datapath sweep: (pattern name, generator kwargs); two ragged
# patterns plus the uniform fine-grained point, all tile-incoherent
SPARSE_PATTERNS = [
    ("fine_iid", lambda key, m, k: fine_spikes(key, m, k, 0.10)),
    ("ragged_mild", lambda key, m, k: ragged_spikes(key, m, k, 0.02, 0.3)),
    ("ragged_extreme", lambda key, m, k: ragged_spikes(key, m, k,
                                                       0.005, 0.6)),
]
SPARSE_PATH_SHAPES = [(512, 256, 256), (1024, 256, 512)]
SPARSE_PATH_BLOCK = 64  # block_m/block_n; block_k doubles as c_block

# fused-step workloads: (name, family, T, B, L, D, heads, head_dim,
# causal) — the SSA shapes of the three spikingformer configs. The two
# vision points are projection-dominated (3D >> 2L: little to hide);
# the token point has L == D, where attention is 2/5 of the serial work
# and the head pipeline hides most of it (hidden_fraction ~ 0.4).
FUSED_CONFIGS = [
    ("spikingformer-4-256", "bn", 4, 2, 64, 256, 8, 32, False),
    ("spikingformer-8-512", "bn", 4, 1, 64, 512, 8, 64, False),
    ("spikingformer-lm", "rope", 4, 1, 256, 256, 4, 64, True),
]
FUSED_DENSITY = 0.25

# layer-program sweep (layer_rows): block sizes for the whole-layer
# occupancy map and the decoded projection path; d_ff = 4 * d_model
# (the spikingformer MLP ratio). Modes: the off row is the sequential
# oracle baseline; decoded rows only exist for the spike-driven (bn)
# family — the token family's ln1-normed currents are dense.
LAYER_L_BLOCK = 32
LAYER_C_BLOCK = 64
LAYER_MODES = [("off", "tile"), ("fused", "tile"), ("fused", "decoded"),
               ("pipeline", "tile"), ("pipeline", "decoded")]


def _time(fn, *args) -> float:
    fn(*args).block_until_ready()           # compile + warm
    ts = []
    for _ in range(REPS):
        t0 = time.perf_counter()
        fn(*args).block_until_ready()
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2] * 1e6   # median, us


def attention_bench(fast: bool = False):
    """Binary-engine sweep: jnp vs MXU kernel vs popcount per SSA shape."""
    from repro.core import engine as E
    from repro.core.attention import spiking_attention
    from repro.core.spiking import SpikingConfig

    scfg = SpikingConfig()
    shapes = ATTN_SHAPES[:2] if fast else ATTN_SHAPES
    rows = []
    for bh, l, d in shapes:
        ks = jax.random.split(jax.random.PRNGKey(bh + l + d), 3)
        q, k, v = ((jax.random.uniform(kk, (bh, l, d)) < ATTN_DENSITY)
                   .astype(jnp.float32) for kk in ks)
        for causal in ATTN_CAUSAL:
            us = {}
            for mode in ("jnp", "mxu_kernel", "popcount"):
                eng = E.EngineConfig(binary=mode)

                def call(q, k, v, eng=eng, causal=causal):
                    return spiking_attention(q, k, v, scfg,
                                             delta_score=0.3,
                                             causal=causal, engine=eng)
                us[mode] = _time(jax.jit(call), q, k, v)
            rows.append({
                "bench": "attention", "shape": [bh, l, d],
                "causal": causal,
                "jnp_us": round(us["jnp"], 1),
                "mxu_us": round(us["mxu_kernel"], 1),
                "popcount_us": round(us["popcount"], 1),
                "mxu_vs_jnp": round(us["jnp"] / us["mxu_kernel"], 3),
                "popcount_vs_mxu": round(
                    us["popcount"] / us["mxu_kernel"], 3),
            })
    return rows


def sparse_path_bench(fast: bool = False):
    """Tile vs decoded datapath on fine-grained / ragged spike patterns,
    plus the sim-vs-measured bucket-schedule cross-validation."""
    import numpy as np

    from repro.core import engine as E
    from repro.kernels.spike_decode import build_schedule, choose_sparse_path
    from repro.kernels.spike_matmul import block_occupancy
    from repro.sim.balance_sim import predicted_schedule

    shapes = SPARSE_PATH_SHAPES[:1] if fast else SPARSE_PATH_SHAPES
    block = SPARSE_PATH_BLOCK
    rows = []
    for m, k, n in shapes:
        for pat_name, gen in SPARSE_PATTERNS:
            # deterministic across processes (str hash() is salted)
            key = jax.random.PRNGKey(m + k + n + sum(map(ord, pat_name)))
            kw, ks = jax.random.split(key)
            s, dens = gen(ks, m, k)
            w = jax.random.normal(kw, (k, n), jnp.float32)
            p = {"w": w}
            tile_eng = E.EngineConfig(mode="sparse", sparse="tile",
                                      block_m=block, block_n=block,
                                      block_k=block)
            dec_eng = tile_eng.replace(sparse="decoded")
            dense_us = _time(jax.jit(
                lambda s, p=p: E.spike_linear(p, s, engine=E.DENSE)), s)
            tile_us = _time(jax.jit(
                lambda s, p=p, e=tile_eng: E.spike_linear(p, s,
                                                          engine=e)), s)
            dec_us = _time(jax.jit(
                lambda s, p=p, e=dec_eng: E.spike_linear(p, s,
                                                         engine=e)), s)
            occ_tiles = block_occupancy(s, block, block)
            tile_skip = float(1.0 - occ_tiles.mean())
            occ_rows = (s != 0).sum(-1).astype(jnp.int32)
            meas = build_schedule(occ_rows, block, block, cap=k)
            dec_frac = float(meas["mac_fraction"]) * \
                meas["padded_cap"] / k
            pred = predicted_schedule(m, k, np.asarray(dens), block,
                                      block, np.random.default_rng(0))
            rows.append({
                "bench": "sparse_path", "pattern": pat_name,
                "shape": [m, k, n], "block": block,
                "measured_sparsity": float(1.0 - s.mean()),
                "dense_us": round(dense_us, 1),
                "tile_us": round(tile_us, 1),
                "decoded_us": round(dec_us, 1),
                "tile_skip_fraction": round(tile_skip, 4),
                "tile_modeled_speedup": round(
                    1.0 / max(1e-9, 1.0 - tile_skip), 3),
                "decoded_mac_fraction": round(dec_frac, 4),
                "decoded_mac_reduction": round(1.0 - dec_frac, 4),
                "decoded_modeled_speedup": round(
                    1.0 / max(1e-9, dec_frac), 3),
                "sched_measured_steps": int(meas["executed"]),
                "sched_predicted_steps": int(pred["executed"]),
                "sched_agreement": round(
                    int(pred["executed"]) / max(1, int(meas["executed"])),
                    3),
                "auto_choice": choose_sparse_path(s, block, block),
            })
    return rows


def fused_bench(fast: bool = False):
    """Fused SSA layer step on the spikingformer-shaped workloads: the
    kernel's executed-step counts -> measured Fig. 5 schedule. All three
    configs run even under ``--fast`` — the counts are what CI gates,
    and the token config is the one whose measured hidden fraction
    demonstrates the overlap (the sweep is three kernel calls, cheap
    even in interpret mode)."""
    del fast
    from repro.core import dual_engine as de
    from repro.core.spiking import SpikingConfig
    from repro.kernels.fused_ssa import fused_ssa, reference_bundle

    scfg = SpikingConfig()
    delta = 0.3
    rows = []
    for name, fam, t, b, l, d, heads, hd, causal in FUSED_CONFIGS:
        q_dim = heads * hd
        # deterministic across processes (str hash() is salted)
        key = jax.random.PRNGKey(t + b + l + d + sum(map(ord, name)))
        kx, kw, ka = jax.random.split(key, 3)
        x = (jax.random.uniform(kx, (t, b, l, d)) < FUSED_DENSITY
             ).astype(jnp.float32)
        # silent warm-up: LIF membranes start discharged, so the first
        # timestep of a sequence often fires nothing — model it with one
        # all-dark (t=0, b=0) slab the occupancy skip can measurably elide
        x = x.at[0, 0].set(0.0)
        w3 = jax.random.normal(kw, (3, d, q_dim), jnp.float32) * d ** -0.5
        if fam == "bn":
            sc, bi = jax.random.split(ka)
            aux = jnp.stack([
                jnp.zeros((q_dim,)), jnp.ones((q_dim,)),
                1.0 + 0.1 * jax.random.normal(sc, (q_dim,)),
                0.1 * jax.random.normal(bi, (q_dim,))])
            aux = jnp.broadcast_to(aux, (3, 4, q_dim))
        else:  # rope: cos/sin table for positions 0..L-1 (theta 1e4)
            half = hd // 2
            freqs = 10000.0 ** (-jnp.arange(0, half, dtype=jnp.float32)
                                / half)
            ang = jnp.arange(l, dtype=jnp.float32)[:, None] * freqs
            aux = jnp.stack([jnp.cos(ang), jnp.sin(ang)])
        kw_args = dict(family=fam, num_heads=heads, head_dim=hd,
                       scale=hd ** -0.5, causal=causal)

        def fused_call(x, w3=w3, aux=aux, kw_args=kw_args):
            return fused_ssa(x, w3, None, aux, delta, **kw_args)[0]

        def seq_call(x, w3=w3, aux=aux, kw_args=kw_args):
            return reference_bundle(x, w3, None, aux, delta, scfg,
                                    **kw_args)
        fused_us = _time(jax.jit(fused_call), x)
        seq_us = _time(jax.jit(seq_call), x)
        _, counts = fused_ssa(x, w3, None, aux, delta, **kw_args)
        m = de.fused_step_metrics(counts, seq=l, k_dim=d, head_dim=hd,
                                  t_steps=t, batch=b)
        rows.append({
            "bench": "fused", "config": name, "family": fam,
            "shape": [t, b, l, d, heads, hd], "causal": causal,
            "fused_us": round(fused_us, 1),
            "sequential_us": round(seq_us, 1),
            # interpret-mode emulation on CPU — informative, never gated
            "wall_ratio": round(seq_us / fused_us, 3),
            "hidden_fraction": round(m["hidden_fraction"], 4),
            "sparse_util": round(m["sparse_util"], 4),
            "binary_util": round(m["binary_util"], 4),
            "executed_q": m["executed_q"], "executed_k": m["executed_k"],
            "executed_v": m["executed_v"],
            "executed_attn": m["executed_attn"],
            "possible_steps": m["possible_steps"],
            "executed_steps": m["executed_steps"],
            "step_reduction": round(m["step_reduction"], 4),
            "proj_skip_fraction": round(m["proj_skip_fraction"], 4),
        })
    return rows


def _dyadic(key, shape, sc):
    """Dyadic-grid weights (multiples of 2^-8): binary-spike x dyadic
    dots accumulate exactly in fp32, so the layer's internal LIF
    thresholds sit away from rounding boundaries and the sim twin's
    jitted spike recompute lands bit-identical to the kernel's."""
    return jnp.round(jax.random.normal(key, shape, jnp.float32)
                     * sc * 256) / 256


def _layer_workload(name, fam, t, b, l, d, heads, hd):
    """Whole-layer operands in the raw kernel layout (the same tensors
    ``core.engine.layer_step`` hands ``kernels/fused_layer``), fp-native
    (scales=None), with the fused_bench dark (t=0, b=0) slab."""
    from repro.core.spiking import SpikingConfig, lif_scan
    q_dim, ff = heads * hd, 4 * d
    key = jax.random.PRNGKey(t + b + l + d + sum(map(ord, name)) + 7)
    kx, kw, ka, k1, k2, ko = jax.random.split(key, 6)
    x = (jax.random.uniform(kx, (t, b, l, d)) < FUSED_DENSITY
         ).astype(jnp.float32)
    x = x.at[0, 0].set(0.0)
    w3 = _dyadic(kw, (3, d, q_dim), d ** -0.5)
    wo = _dyadic(ko, (q_dim, d), q_dim ** -0.5)
    w1 = _dyadic(k1, (d, ff), d ** -0.5)
    w2 = _dyadic(k2, (ff, d), ff ** -0.5)
    if fam == "bn":
        def rows(k, n):
            a, b2 = jax.random.split(k)
            return jnp.stack([jnp.zeros((n,)), jnp.ones((n,)),
                              1.0 + 0.1 * jax.random.normal(a, (n,)),
                              0.1 * jax.random.normal(b2, (n,))])
        ks = jax.random.split(ka, 6)
        auxp = jnp.stack([rows(k, q_dim) for k in ks[:3]])
        auxo, aux1, aux2 = (rows(ks[3], d), rows(ks[4], ff),
                            rows(ks[5], d))
        s = lif_scan(x, SpikingConfig())[0]         # spikes feed q/k/v
    else:  # rope: cos/sin tables; s is the ln1-normed residual stream
        half = hd // 2
        freqs = 10000.0 ** (-jnp.arange(0, half, dtype=jnp.float32)
                            / half)
        ang = jnp.arange(l, dtype=jnp.float32)[:, None] * freqs
        auxp = jnp.stack([jnp.cos(ang), jnp.sin(ang)])
        auxo = jnp.ones((1, d), jnp.float32)        # ln2 rmsnorm scale
        aux1 = aux2 = None
        x32 = x.astype(jnp.float32)
        s = (x32 * jax.lax.rsqrt(
            jnp.mean(x32 * x32, axis=-1, keepdims=True) + 1e-6)
        ).astype(x.dtype)
    return (x, s, w3, wo, w1, w2, None, auxp, auxo, aux1, aux2), ff


def _proj_kv_spikes(s, w3, auxp, fam, heads, hd):
    """K/V projection spikes as the fused kernel's projection phases
    emit them — the measured side of the ``binary_block_schedule`` sim
    cross-validation. Jitted: the kernel body is always compiled, and
    compiled dots FMA-contract, so an eager recompute could flip a
    threshold-boundary spike."""
    from repro.core.spiking import SpikingConfig, lif_scan

    @jax.jit
    def f(s, w3, auxp):
        out = []
        for i, roped in ((1, True), (2, False)):
            cur = jnp.dot(s, w3[i], preferred_element_type=jnp.float32)
            y = cur.astype(s.dtype)
            if fam == "bn":
                y32 = y.astype(jnp.float32)
                y32 = (y32 - auxp[i, 0]) * jax.lax.rsqrt(auxp[i, 1] + 1e-5)
                y = (y32 * auxp[i, 2] + auxp[i, 3]).astype(s.dtype)
            elif roped:
                half = hd // 2
                t, b, l, qd = y.shape
                yh = y.reshape(t, b, l, heads, hd)
                x1 = yh[..., :half].astype(jnp.float32)
                x2 = yh[..., half:].astype(jnp.float32)
                c = auxp[0][None, None, :, None, :]
                sn = auxp[1][None, None, :, None, :]
                yh = jnp.concatenate([x1 * c - x2 * sn, x2 * c + x1 * sn],
                                     -1).astype(y.dtype)
                y = yh.reshape(t, b, l, qd)
            out.append(lif_scan(y, SpikingConfig())[0])
        return tuple(out)
    return f(s, w3, auxp)


def layer_bench(fast: bool = False):
    """Layer-program step (``kernels/fused_layer`` via the engine's
    ``layer_step`` surface) on the three spikingformer-shaped whole-layer
    workloads: off (sequential oracle) vs fused vs pipeline x tile vs
    decoded. Counts-derived metrics are deterministic and CI-gated; wall
    clock is interpret-mode emulation, informative only. Each fused /
    pipeline row also cross-validates ``sim/balance_sim
    .binary_block_schedule`` — the numpy twin of the kernel's
    binary-phase occupancy map — against the measured ``counts[:, 3:5]``
    (``sim_binary_agreement`` = predicted / measured executed binary
    sub-blocks; sub-block-exact in practice). All three configs run even
    under ``--fast``: the counts are what CI gates, and the token config
    carries the layer-level hidden-fraction acceptance floor."""
    del fast
    import numpy as np

    from repro.core import dual_engine as de
    from repro.core.spiking import SpikingConfig
    from repro.kernels.fused_layer import fused_layer, reference_layer
    from repro.sim.balance_sim import binary_block_schedule

    scfg = SpikingConfig()
    delta = 0.3
    rows = []
    for name, fam, t, b, l, d, heads, hd, causal in FUSED_CONFIGS:
        ops, ff = _layer_workload(name, fam, t, b, l, d, heads, hd)
        args = ops + (delta,)
        kw_args = dict(family=fam, num_heads=heads, head_dim=hd,
                       scale=hd ** -0.5, causal=causal)
        seq_us = _time(jax.jit(lambda *a, k=kw_args: reference_layer(
            *a, scfg, **k)), *args)
        ksp, vsp = _proj_kv_spikes(ops[1], ops[2], ops[7], fam, heads, hd)
        pred = binary_block_schedule(np.asarray(ksp), np.asarray(vsp),
                                     heads, LAYER_L_BLOCK, delta,
                                     binarize=scfg.binarize_scores)
        pred_exec = int(pred.sum())
        for overlap, sparse in LAYER_MODES:
            if sparse == "decoded" and fam != "bn":
                continue
            base = {"bench": "layer", "config": name, "family": fam,
                    "shape": [t, b, l, d, heads, hd], "causal": causal,
                    "overlap": overlap, "sparse": sparse,
                    "sequential_us": round(seq_us, 1)}
            if overlap == "off":
                rows.append(dict(base, layer_us=round(seq_us, 1),
                                 wall_ratio=1.0, hidden_fraction=0.0,
                                 step_reduction=0.0))
                continue
            pipe = overlap == "pipeline"

            def call(*a, sp=sparse, pi=pipe, k=kw_args):
                return fused_layer(*a, sparse=sp, pipeline=pi,
                                   l_block=LAYER_L_BLOCK,
                                   c_block=LAYER_C_BLOCK, **k)[0]
            layer_us = _time(jax.jit(call), *args)
            _, counts = fused_layer(*args, sparse=sparse, pipeline=pipe,
                                    l_block=LAYER_L_BLOCK,
                                    c_block=LAYER_C_BLOCK, **kw_args)
            meas = np.asarray(counts)[:, 3:5, :]
            m = de.fused_step_metrics(
                counts, seq=l, k_dim=d, head_dim=hd, t_steps=t, batch=b,
                d_model=d, d_ff=ff, l_block=LAYER_L_BLOCK, sparse=sparse,
                c_block=LAYER_C_BLOCK, pipeline=pipe)
            rows.append(dict(
                base, layer_us=round(layer_us, 1),
                wall_ratio=round(seq_us / layer_us, 3),
                hidden_fraction=round(m["hidden_fraction"], 4),
                qkt_hidden_fraction=round(m["qkt_hidden_fraction"], 4),
                qktv_hidden_fraction=round(m["qktv_hidden_fraction"], 4),
                sparse_util=round(m["sparse_util"], 4),
                binary_util=round(m["binary_util"], 4),
                pipeline_iters=m["pipeline_iters"],
                executed_steps=m["executed_steps"],
                possible_steps=m["possible_steps"],
                step_reduction=round(m["step_reduction"], 4),
                sim_binary_agreement=round(
                    pred_exec / max(1, int(meas.sum())), 4),
                sim_binary_exact=bool(np.array_equal(pred, meas)),
                **{f"executed_{ph}": m[f"executed_{ph}"]
                   for ph in de.LAYER_PHASE_NAMES}))
    return rows


def bench(fast: bool = False):
    from repro.core import engine as E
    from repro.core.dual_engine import (measured_overlap_efficiency,
                                        measured_schedule)
    from repro.kernels.spike_matmul import block_occupancy

    shapes = SHAPES[:2] if fast else SHAPES
    rows = []
    for m, k, n in shapes:
        for block in BLOCKS:
            for sparsity in SPARSITIES:
                key = jax.random.PRNGKey(m + block + int(sparsity * 100))
                kw, ks = jax.random.split(key)
                s = coherent_spikes(ks, m, k, block, sparsity)
                w = jax.random.normal(kw, (k, n), jnp.float32)
                p = {"w": w}
                sparse_eng = E.EngineConfig(mode="sparse", block_m=block,
                                            block_n=block, block_k=block)
                dense_us = _time(jax.jit(
                    lambda s, p=p: E.spike_linear(p, s, engine=E.DENSE)), s)
                sparse_us = _time(jax.jit(
                    lambda s, p=p, e=sparse_eng: E.spike_linear(
                        p, s, engine=e)), s)
                occ = block_occupancy(s, min(block, m), min(block, k))
                skip = float(1.0 - occ.mean())
                tiles = occ.size  # MAC reduction is bounded by the grid
                rows.append({
                    "bench": "linear",
                    "shape": [m, k, n], "block": block,
                    "sparsity": sparsity,
                    "measured_sparsity": float(1.0 - s.mean()),
                    "dense_us": round(dense_us, 1),
                    "sparse_us": round(sparse_us, 1),
                    "wall_speedup": round(dense_us / sparse_us, 3),
                    "skip_fraction": round(skip, 4),
                    "modeled_speedup": round(
                        min(1.0 / max(1e-9, 1.0 - skip), float(tiles)), 3),
                })
    attn_rows = attention_bench(fast=fast)
    sp_rows = sparse_path_bench(fast=fast)
    fu_rows = fused_bench(fast=fast)
    la_rows = layer_bench(fast=fast)
    med = lambda xs: sorted(xs)[len(xs) // 2]
    sparse_med = med([r["sparse_us"] for r in rows])
    mxu_med = med([r["mxu_us"] for r in attn_rows])
    _, _, overlapped, serial = measured_schedule(sparse_med, mxu_med)
    derived = {
        "backend": jax.default_backend(),
        "interpret_mode": jax.default_backend() != "tpu",
        "points": len(rows),
        "max_modeled_speedup": max(r["modeled_speedup"] for r in rows),
        "mean_skip_at_0.9": round(sum(
            r["skip_fraction"] for r in rows if r["sparsity"] == 0.9) /
            max(1, sum(1 for r in rows if r["sparsity"] == 0.9)), 4),
        "attention_points": len(attn_rows),
        "mxu_vs_jnp_median": med([r["mxu_vs_jnp"] for r in attn_rows]),
        "popcount_vs_mxu_median": med(
            [r["popcount_vs_mxu"] for r in attn_rows]),
        # tile-vs-decoded on fine-grained/ragged patterns (DESIGN.md §9):
        # the tile skip is ~0 there by construction, so the decoded MAC
        # reduction is the whole sparse-engine story in that regime
        "sparse_path_points": len(sp_rows),
        "decoded_max_modeled_speedup": max(
            r["decoded_modeled_speedup"] for r in sp_rows),
        "tile_skip_on_ragged_max": max(
            r["tile_skip_fraction"] for r in sp_rows),
        "decoded_auto_wins": sum(
            1 for r in sp_rows if r["auto_choice"] == "decoded"),
        "sched_agreement_median": med(
            [r["sched_agreement"] for r in sp_rows]),
        # Fig. 5 overlap model on measured engine medians (us events)
        "measured_overlap": {
            "sparse_op_us": round(sparse_med, 1),
            "binary_op_us": round(mxu_med, 1),
            "overlapped_us": round(overlapped, 1),
            "serial_us": round(serial, 1),
            "hidden_fraction": round(
                measured_overlap_efficiency(sparse_med, mxu_med), 4),
        },
        # fused layer step: hidden fraction measured from the kernel's
        # own executed-step counts (per-row detail in fused_rows)
        "fused_overlap": {
            "points": len(fu_rows),
            "max_hidden_fraction": max(
                r["hidden_fraction"] for r in fu_rows),
            "best_config": max(fu_rows,
                               key=lambda r: r["hidden_fraction"])
            ["config"],
        },
        # layer-program step: the whole-layer occupancy map's measured
        # binary-hidden fraction (per-row detail in layer_rows); the
        # token config's fused/tile row carries the CI floor vs the
        # SSA-only bundle's hidden fraction
        "layer_overlap": {
            "points": len(la_rows),
            "token_hidden_fraction": next(
                r["hidden_fraction"] for r in la_rows
                if r["config"] == "spikingformer-lm"
                and r["overlap"] == "fused"),
            "min_hidden_fraction": min(
                r["hidden_fraction"] for r in la_rows
                if r["overlap"] != "off"),
            "sim_binary_exact_all": all(
                r["sim_binary_exact"] for r in la_rows
                if r["overlap"] != "off"),
        },
    }
    return rows + attn_rows + sp_rows + fu_rows + la_rows, derived


def to_blob(rows, derived):
    """Split the tagged row list into the artifact layout
    ({'rows': linear, 'attention_rows': attention, 'sparse_path_rows':
    tile-vs-decoded, 'fused_rows': fused SSA bundle, 'layer_rows':
    whole-layer program, 'derived': ...})."""
    return {"rows": [r for r in rows
                     if r.get("bench") not in ("attention", "sparse_path",
                                               "fused", "layer")],
            "attention_rows": [r for r in rows
                               if r.get("bench") == "attention"],
            "sparse_path_rows": [r for r in rows
                                 if r.get("bench") == "sparse_path"],
            "fused_rows": [r for r in rows if r.get("bench") == "fused"],
            "layer_rows": [r for r in rows if r.get("bench") == "layer"],
            "derived": derived}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--out", default="artifacts/dual_engine_bench.json")
    args = ap.parse_args()
    rows, derived = bench(fast=args.fast)
    blob = to_blob(rows, derived)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(blob, f, indent=1)
    print("shape,block,sparsity,dense_us,sparse_us,wall_speedup,"
          "skip_fraction,modeled_speedup")
    for r in blob["rows"]:
        print(f"{'x'.join(map(str, r['shape']))},{r['block']},"
              f"{r['sparsity']},{r['dense_us']},{r['sparse_us']},"
              f"{r['wall_speedup']},{r['skip_fraction']},"
              f"{r['modeled_speedup']}")
    print("shape,causal,jnp_us,mxu_us,popcount_us,mxu_vs_jnp,"
          "popcount_vs_mxu")
    for r in blob["attention_rows"]:
        print(f"{'x'.join(map(str, r['shape']))},{r['causal']},"
              f"{r['jnp_us']},{r['mxu_us']},{r['popcount_us']},"
              f"{r['mxu_vs_jnp']},{r['popcount_vs_mxu']}")
    print("pattern,shape,tile_skip,decoded_mac_reduction,"
          "decoded_modeled_speedup,sched_agreement,auto")
    for r in blob["sparse_path_rows"]:
        print(f"{r['pattern']},{'x'.join(map(str, r['shape']))},"
              f"{r['tile_skip_fraction']},{r['decoded_mac_reduction']},"
              f"{r['decoded_modeled_speedup']},{r['sched_agreement']},"
              f"{r['auto_choice']}")
    print("config,shape,hidden_fraction,sparse_util,binary_util,"
          "step_reduction,proj_skip_fraction,fused_us,sequential_us")
    for r in blob["fused_rows"]:
        print(f"{r['config']},{'x'.join(map(str, r['shape']))},"
              f"{r['hidden_fraction']},{r['sparse_util']},"
              f"{r['binary_util']},{r['step_reduction']},"
              f"{r['proj_skip_fraction']},{r['fused_us']},"
              f"{r['sequential_us']}")
    print("config,overlap,sparse,hidden_fraction,step_reduction,"
          "sim_binary_agreement,layer_us,sequential_us")
    for r in blob["layer_rows"]:
        print(f"{r['config']},{r['overlap']},{r['sparse']},"
              f"{r['hidden_fraction']},{r['step_reduction']},"
              f"{r.get('sim_binary_agreement', '-')},{r['layer_us']},"
              f"{r['sequential_us']}")
    print(json.dumps(derived))


if __name__ == "__main__":
    main()
