"""Dual-engine latency-hiding pipeline schedule (paper Section III-C,
Eq. 3/4) — analytic model *and* measurement consumer.

FireFly-T overlaps the sparse engine (Q/K/V projections) with the binary
engine (QK^T, QK^T V) across attention heads. This module holds the
discrete-event model of that schedule (Fig. 5) and, since the fused
dual-engine kernel landed (``kernels/fused_ssa.py``), the consumer that
turns the kernel's *measured* per-phase executed-step counts into a
hidden-fraction / utilization report (:func:`fused_step_metrics`). It is
used by:

* ``benchmarks/paper_figures.py``        — the Fig. 5 spatial-temporal
  overlap diagram (``pipeline_schedule``),
* ``benchmarks/dual_engine_bench.py``    — the measured-overlap rows
  (``measured_schedule`` on wall-clock medians; ``fused_step_metrics``
  on the fused kernel's step counts),
* ``examples/dual_engine_walkthrough.py``— the Eq. 4 engine-sizing rule
  (``required_binary_parallelism``) used to pick ``P_B*`` for a network.

On TPU the same overlap re-appears as HBM-prefetch ∥ MXU pipelining inside
the fused attention kernel and as compute/collective overlap at the
distribution layer (see DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence, Tuple, Union


@dataclasses.dataclass(frozen=True)
class EngineParallelism:
    """Hardware parallelism knobs (Table II)."""
    P_Ts: int = 2
    P_Fx: int = 4
    P_Ci: int = 16
    P_Co: int = 64
    # binary engine systolic array + inner-product width
    P_Bm: int = 4
    P_Bn: int = 4
    P_Bk: int = 32

    @property
    def P_s(self) -> int:
        return self.P_Ts * self.P_Fx * self.P_Ci * self.P_Co

    @property
    def P_b(self) -> int:
        return self.P_Bm * self.P_Bn * self.P_Bk


@dataclasses.dataclass(frozen=True)
class AttentionWorkload:
    """Per-head attention workload (Eq. 3)."""
    T_s: int
    F_h: int
    F_w: int
    C_i: int          # embedding dim d
    P_Co: int         # output-channel tile == per-head dim in the schedule
    heads: int = 8

    @property
    def L(self) -> int:
        return self.F_h * self.F_w

    def W_s(self) -> int:
        """Sparse-engine work per head per projection (MACs)."""
        return self.T_s * self.L * self.C_i * self.P_Co

    def W_b(self) -> int:
        """Binary-engine work per head per attention matmul (MACs)."""
        return self.T_s * self.L * self.L * self.P_Co


def required_binary_parallelism(w: AttentionWorkload, p: EngineParallelism) -> float:
    """Eq. 4: P_b ~= 2/3 * (Fh*Fw / Ci) * P_s for balanced overlap."""
    return 2.0 / 3.0 * (w.L / w.C_i) * p.P_s


# Per-head timing inputs: a scalar (every op identical — the original
# two-scalar model), or a per-head sequence whose entries are scalars or
# (Q, K, V) triples (sparse) / (QK^T, QK^TV) pairs (binary).
PerHead = Union[float, Sequence]


def _sparse_triples(ts: PerHead, heads: int) -> List[Tuple[float, ...]]:
    if not isinstance(ts, Sequence):
        return [(float(ts),) * 3] * heads
    if len(ts) != heads:
        raise ValueError(f"per-head sparse timings: got {len(ts)} entries "
                         f"for {heads} heads")
    return [(float(e),) * 3 if not isinstance(e, Sequence)
            else tuple(float(x) for x in e) for e in ts]


def _binary_pairs(tb: PerHead, heads: int) -> List[Tuple[float, ...]]:
    if not isinstance(tb, Sequence):
        return [(float(tb),) * 2] * heads
    if len(tb) != heads:
        raise ValueError(f"per-head binary timings: got {len(tb)} entries "
                         f"for {heads} heads")
    return [(float(e),) * 2 if not isinstance(e, Sequence)
            else tuple(float(x) for x in e) for e in tb]


def _event_schedule(ts: PerHead, tb: PerHead, heads: int
                    ) -> Tuple[List[tuple], List[tuple], float, float]:
    """Core event loop shared by the analytic and measured schedules:
    the sparse engine serially computes Q_h, K_h, V_h per head (``ts``
    each); the binary engine computes ``QK^T_h`` once Q_h,K_h are done
    and ``QK^T V_h`` once V_h is done (``tb`` each). ``ts``/``tb`` are
    scalars or per-head sequences (see :data:`PerHead`); the scalar path
    is numerically pinned to the original two-scalar model."""
    trips = _sparse_triples(ts, heads)
    pairs = _binary_pairs(tb, heads)
    sparse_events, binary_events = [], []
    t_sparse = 0.0
    qk_done = {}
    v_done = {}
    for h in range(heads):
        for name, dt in zip(("Q", "K", "V"), trips[h]):
            sparse_events.append((f"{name}{h}", t_sparse, t_sparse + dt))
            t_sparse += dt
            if name == "K":
                qk_done[h] = t_sparse
            if name == "V":
                v_done[h] = t_sparse
    t_bin = 0.0
    for h in range(heads):
        t_qk, t_qkv = pairs[h]
        start = max(t_bin, qk_done[h])
        binary_events.append((f"QK^T {h}", start, start + t_qk))
        t_bin = start + t_qk
        start = max(t_bin, v_done[h])
        binary_events.append((f"QK^TV {h}", start, start + t_qkv))
        t_bin = start + t_qkv

    total_overlapped = max(t_sparse, t_bin if binary_events else 0.0)
    if not isinstance(tb, Sequence):
        # the original scalar expression, verbatim (float-op-for-float-op:
        # the scalar path is pinned numerically unchanged)
        total_serial = t_sparse + 2 * float(tb) * heads
    else:
        total_serial = t_sparse + sum(t_qk + t_qkv
                                      for t_qk, t_qkv in pairs)
    return sparse_events, binary_events, total_overlapped, total_serial


def pipeline_schedule(w: AttentionWorkload, p: EngineParallelism,
                      sparsity: float = 0.0
                      ) -> Tuple[List[tuple], List[tuple], int, int]:
    """Discrete-event schedule of the latency-hiding pipeline (Fig. 5).

    Op latencies come from the analytic MAC model (Eq. 3 work over
    Table II parallelism; sparse throughput scales with input density
    when skipping is on). Returns (sparse_events, binary_events,
    total_overlapped, total_serial); events are (name, start, end) in
    cycles.
    """
    ts = w.W_s() / (p.P_s / max(1e-9, 1.0 - sparsity))  # sparse op latency
    tb = w.W_b() / p.P_b                                # binary op latency
    se, be, overlapped, serial = _event_schedule(ts, tb, w.heads)
    return se, be, math.ceil(overlapped), math.ceil(serial)


def measured_schedule(sparse_op_us: PerHead, binary_op_us: PerHead,
                      heads: int = 8
                      ) -> Tuple[List[tuple], List[tuple], float, float]:
    """Fig. 5 schedule fed with *measured* engine timings instead of the
    analytic MAC model — e.g. the per-call medians
    ``benchmarks/dual_engine_bench.py`` writes to
    ``artifacts/dual_engine_bench.json`` (``sparse_us`` from the matmul
    sweep, ``mxu_us`` from the attention sweep). Each input is a scalar
    (all heads/ops identical) or a per-head sequence — entries scalars or
    (Q, K, V) triples / (QK^T, QK^TV) pairs, e.g. derived from the fused
    kernel's per-phase executed-step counts. Events are in the same unit
    as the inputs; returns (sparse_events, binary_events,
    total_overlapped, total_serial).
    """
    if not isinstance(sparse_op_us, Sequence):
        sparse_op_us = float(sparse_op_us)
    if not isinstance(binary_op_us, Sequence):
        binary_op_us = float(binary_op_us)
    return _event_schedule(sparse_op_us, binary_op_us, heads)


def measured_overlap_efficiency(sparse_op_us: PerHead,
                                binary_op_us: PerHead,
                                heads: int = 8) -> float:
    """Fraction of the serial dual-engine latency the overlap hides,
    from measured timings: 1 - overlapped/serial."""
    _, _, overlapped, serial = measured_schedule(sparse_op_us,
                                                 binary_op_us, heads)
    if serial <= 0:
        return 0.0
    return 1.0 - overlapped / serial


def schedule_metrics(sparse_op_us: PerHead, binary_op_us: PerHead,
                     heads: int = 8) -> Dict[str, float]:
    """Hidden fraction *and* per-engine utilization of the Fig. 5
    schedule: utilization is each engine's busy time over the overlapped
    makespan (1.0 = that engine never stalls; the paper sizes ``P_B*`` so
    both stay near 1 — Eq. 4)."""
    se, be, overlapped, serial = measured_schedule(sparse_op_us,
                                                   binary_op_us, heads)
    sparse_busy = sum(e - s for _, s, e in se)
    binary_busy = sum(e - s for _, s, e in be)
    return {
        "overlapped": overlapped,
        "serial": serial,
        "hidden_fraction": 0.0 if serial <= 0 else 1.0 - overlapped / serial,
        "sparse_util": 0.0 if overlapped <= 0 else sparse_busy / overlapped,
        "binary_util": 0.0 if overlapped <= 0 else binary_busy / overlapped,
    }


def fused_step_metrics(counts, *, seq: int, k_dim: int, head_dim: int,
                       t_steps: int, batch: int) -> Dict[str, float]:
    """Measured overlap report from the fused kernel's executed-step
    counts (``kernels/fused_ssa.fused_ssa``'s ``(H, 4)`` int32 output:
    executed Q/K/V projection dots and attention dots per head).

    This is the "measured, not modeled" hidden fraction: op durations in
    the Fig. 5 schedule are the *executed* MACs of each phase — a
    projection sub-step the kernel skipped (all-dark spike slab) simply
    isn't there — with exact per-dot weights (projection dot = L*K*hd
    MACs, attention dot = L*L*hd). Deterministic for a fixed input, so
    CI gates it (benchmarks/check_regression.py).
    """
    rows = [[int(c) for c in row] for row in counts]
    heads = len(rows)
    w_proj = seq * k_dim * head_dim          # MACs per executed proj dot
    w_attn = seq * seq * head_dim            # MACs per executed attn dot
    sparse = [(r[0] * w_proj, r[1] * w_proj, r[2] * w_proj) for r in rows]
    binary = [(r[3] // 2 * w_attn, (r[3] - r[3] // 2) * w_attn)
              for r in rows]
    m = schedule_metrics(sparse, binary, heads)
    exec_q = sum(r[0] for r in rows)
    exec_k = sum(r[1] for r in rows)
    exec_v = sum(r[2] for r in rows)
    exec_attn = sum(r[3] for r in rows)
    possible_proj = 3 * t_steps * batch * heads
    possible_attn = 2 * t_steps * batch * heads
    executed = exec_q + exec_k + exec_v + exec_attn
    possible = possible_proj + possible_attn
    m.update({
        "heads": heads,
        "executed_q": exec_q, "executed_k": exec_k, "executed_v": exec_v,
        "executed_attn": exec_attn,
        "possible_steps": possible,
        "executed_steps": executed,
        # sequential baseline executes every sub-step back-to-back; the
        # fused step both *skips* dark projection slabs and *hides*
        # binary work behind sparse work — this is the skip half:
        "step_reduction": 0.0 if possible == 0
        else 1.0 - executed / possible,
        "proj_skip_fraction": 0.0 if possible_proj == 0
        else 1.0 - (exec_q + exec_k + exec_v) / possible_proj,
    })
    return m


def pipeline_efficiency(w: AttentionWorkload, p: EngineParallelism,
                        sparsity: float = 0.0) -> float:
    """Fraction of attention latency hidden: 1 -> perfect (O(3TsLd^2))."""
    _, _, overlapped, serial = pipeline_schedule(w, p, sparsity)
    ideal = 3 * w.heads * (w.W_s() / (p.P_s / max(1e-9, 1.0 - sparsity)))
    if overlapped <= 0:
        return 1.0
    return min(1.0, ideal / overlapped)


def complexity_reduction(w: AttentionWorkload) -> Tuple[int, int]:
    """(serial, overlapped) op counts: O(3TsLd^2 + 2TsL^2 d) -> O(3TsLd^2).

    Uses d == heads * P_Co as the full embedding dim.
    """
    d = w.C_i
    serial = 3 * w.T_s * w.L * d * d + 2 * w.T_s * w.L * w.L * d
    overlapped = 3 * w.T_s * w.L * d * d
    return serial, overlapped
