"""Dense decoder-only transformer family.

Covers: nemotron-4-15b (full attn, squared-ReLU), gemma3-12b (5:1
local:global, qk-norm), h2o-danube-3-4b (SWA), granite-20b (MQA),
llava-next-mistral-7b backbone (SWA; see vlm.py for the frontend).

Execution modes:
  forward      — full-sequence (train / prefill); lax.scan over layers,
                 chunked flash attention (banded for SWA / local layers).
  decode_step  — one token against a KV cache (full or rolling window).
  spiking mode — activations are LIF spike trains over T_s time steps and
                 attention is binary attention (the paper's SSA); enabled by
                 cfg.spiking (DESIGN.md §5).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.bitpack import pack_bits, unpack_bits
from repro.core.spiking import binarize, lif_scan
from repro.parallel.sharding import constrain
from . import nn

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _layer_init(key, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    p = {
        "ln1": nn.rmsnorm_init(cfg.d_model, dt),
        "wq": nn.linear_init(ks[0], cfg.d_model, cfg.q_dim, dtype=dt),
        "wk": nn.linear_init(ks[1], cfg.d_model, cfg.kv_dim, dtype=dt),
        "wv": nn.linear_init(ks[2], cfg.d_model, cfg.kv_dim, dtype=dt),
        "wo": nn.linear_init(ks[3], cfg.q_dim, cfg.d_model,
                             std=1.0 / math.sqrt(cfg.q_dim * 2 * cfg.num_layers),
                             dtype=dt),
        "ln2": nn.rmsnorm_init(cfg.d_model, dt),
        "mlp": nn.mlp_init(ks[4], cfg.d_model, cfg.d_ff, gated=cfg.gated,
                           dtype=dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = nn.rmsnorm_init(cfg.head_dim, dt)
        p["k_norm"] = nn.rmsnorm_init(cfg.head_dim, dt)
    if cfg.spiking is not None:
        p["delta"] = jnp.asarray(cfg.spiking.attn_threshold_init, jnp.float32)
    return p


def init(cfg: ModelConfig, key) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.dtype)
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    params: Dict[str, Any] = {
        "embed": nn.embedding_init(k_embed, cfg.vocab_size, cfg.d_model, dt),
        "final_norm": nn.rmsnorm_init(cfg.d_model, dt),
    }
    if cfg.attn_type == "local_global":
        g = cfg.num_layers // cfg.global_every
        keys = jax.random.split(k_layers, cfg.num_layers).reshape(
            g, cfg.global_every, 2)
        params["groups"] = jax.vmap(jax.vmap(lambda k: _layer_init(k, cfg)))(keys)
    else:
        keys = jax.random.split(k_layers, cfg.num_layers)
        params["layers"] = jax.vmap(lambda k: _layer_init(k, cfg))(keys)
    if not cfg.tie_embeddings:
        params["lm_head"] = nn.linear_init(k_head, cfg.d_model,
                                           cfg.vocab_size, dtype=dt)
    return params


# ---------------------------------------------------------------------------
# layer application (full sequence)
# ---------------------------------------------------------------------------


def _project_qkv(p, cfg: ModelConfig, h, positions, repeat_kv: bool = False):
    """h: (..., S, D) -> q (..., S, H, hd), k/v (..., S, KH, hd), roped.

    ``repeat_kv`` broadcasts KV heads up to H *before* attention (full-seq
    paths): with heads TP-sharded over 'model', the grouped-GQA reshape
    (H -> KH x rep) would cross shard boundaries and force all-gathers —
    repeating locally keeps every reshape sharding-aligned (each shard
    expands only its own KV slice). Decode paths keep KV unrepeated (the
    cache stores KH heads).
    """
    lead = h.shape[:-2]
    s = h.shape[-2]
    q = nn.linear(p["wq"], h).reshape(*lead, s, cfg.num_heads, cfg.head_dim)
    k = nn.linear(p["wk"], h).reshape(*lead, s, cfg.num_kv_heads, cfg.head_dim)
    v = nn.linear(p["wv"], h).reshape(*lead, s, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = nn.rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = nn.rmsnorm(p["k_norm"], k, cfg.norm_eps)
    # rope operates on (B, L, H, D): fold extra leading dims
    q = nn.rope(q.reshape(-1, s, cfg.num_heads, cfg.head_dim), positions,
                cfg.rope_theta).reshape(*lead, s, cfg.num_heads, cfg.head_dim)
    k = nn.rope(k.reshape(-1, s, cfg.num_kv_heads, cfg.head_dim), positions,
                cfg.rope_theta).reshape(*lead, s, cfg.num_kv_heads, cfg.head_dim)
    if repeat_kv and cfg.num_heads != cfg.num_kv_heads:
        rep = cfg.num_heads // cfg.num_kv_heads
        k = jnp.repeat(k, rep, axis=-2)
        v = jnp.repeat(v, rep, axis=-2)
    prefix = (None,) * (len(lead) - 1) + ("batch", "seq")
    kv_name = "heads" if repeat_kv else "kv_heads"
    q = constrain(q, *prefix, "heads", None)
    k = constrain(k, *prefix, kv_name, None)
    v = constrain(v, *prefix, kv_name, None)
    return q, k, v


def _attend_full_seq(cfg: ModelConfig, kind: str, q, k, v, delta=None):
    """kind: 'full' | 'window'. Shapes (B', S, H/KH, hd)."""
    window = cfg.window if kind == "window" else None
    if cfg.spiking is not None:
        if window is None:
            # binary-engine dispatch (jnp / MXU kernel / popcount) via the
            # ambient engine; (B', S, H, hd) -> (B', H, S, hd) puts (S, hd)
            # in the primitive's trailing position. KV heads are already
            # repeated to H here (repeat_kv=True in _project_qkv).
            from repro.core.attention import spiking_attention
            swap = lambda u: u.transpose(0, 2, 1, 3)
            ctx = spiking_attention(swap(q), swap(k), swap(v), cfg.spiking,
                                    delta_score=delta, causal=True)
            return swap(ctx)
        # sliding-window spiking SSA keeps the banded jnp dataflow (the
        # fused kernel's block skip is causal-only for now)
        return nn.binary_flash_attention(
            q, k, v, delta=delta, alpha=cfg.spiking.surrogate_alpha,
            causal=True, window=window,
            binarize_scores=cfg.spiking.binarize_scores)
    if window is not None:
        return nn.banded_flash_attention(q, k, v, window=window)
    return nn.flash_attention(q, k, v, causal=True)


def _spike(x, cfg: ModelConfig, t_steps: int):
    """LIF over the time axis; x: (T, B, S, D) currents -> spikes."""
    spikes, _ = lif_scan(x, cfg.spiking)
    return spikes


def apply_layer(p, cfg: ModelConfig, x, positions, kind: str, train: bool):
    """x: (B, S, D) or (T, B, S, D) in spiking mode."""
    spiking = cfg.spiking is not None
    if spiking and kind == "full":
        # the whole layer program — ln1 + SSA bundle + wo + residual +
        # ln2 + spiking MLP + residual — is engine-owned: with
        # overlap='fused' | 'pipeline' both overlay halves run as one
        # Pallas grid spanning the layer (Fig. 5, the MLP phases riding
        # the same wavefront; pipeline adds the timestep axis to the
        # grid), otherwise the engine composes the sequential reference
        # (which still hands the bundle to ssa_step_causal). The
        # sliding-window branch below keeps its banded jnp dataflow
        # (the fused grid is full-attention only).
        from repro.core.engine import layer_step_causal
        return layer_step_causal(p, cfg, x, positions, train=train)
    h = nn.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if spiking:
        t = x.shape[0]
        q, k, v = _project_qkv(p, cfg, h, positions, repeat_kv=True)
        q, k, v = (_spike(u, cfg, t) for u in (q, k, v))
        fold = lambda u: u.reshape(-1, *u.shape[2:])
        attn = _attend_full_seq(cfg, kind, fold(q), fold(k), fold(v),
                                delta=p["delta"])
        attn = attn.reshape(*x.shape[:-1], cfg.q_dim)
    else:
        q, k, v = _project_qkv(p, cfg, h, positions, repeat_kv=True)
        attn = _attend_full_seq(cfg, kind, q, k, v)
        attn = attn.reshape(*x.shape[:-1], cfg.q_dim)
    # q_dim stays 'model'-sharded into the row-parallel wo (§Perf F2 —
    # constraining to replicated here forced a (B,S,H,hd) all-gather)
    attn = constrain(attn, "batch", "seq", "model")
    x = x + nn.linear(p["wo"], attn)
    h2 = nn.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if spiking:
        up = nn.linear(p["mlp"]["up"], h2)
        hidden = _spike(up, cfg, x.shape[0])
        x = x + nn.linear(p["mlp"]["down"], hidden)
    else:
        x = x + nn.mlp(p["mlp"], h2, cfg.act)
    return constrain(x, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def forward(params, cfg: ModelConfig, batch, *, train: bool = False,
            inputs_embeds: Optional[jax.Array] = None):
    """batch: {'tokens': (B, S)}; returns (logits (B, S, V), aux dict)."""
    tokens = batch["tokens"]
    x = nn.embed(params["embed"], tokens) if inputs_embeds is None \
        else inputs_embeds
    x = constrain(x, "batch", "seq", "embed")
    s = x.shape[-2]
    positions = jnp.arange(s)
    if cfg.spiking is not None:
        x = jnp.broadcast_to(x[None], (cfg.spiking.time_steps,) + x.shape)

    layer_fn = apply_layer
    if cfg.remat and train:
        layer_fn = jax.checkpoint(apply_layer,
                                  static_argnums=(1, 4, 5),
                                  policy=jax.checkpoint_policies.nothing_saveable)

    if cfg.attn_type == "local_global":
        def group_body(x, gp):
            for j in range(cfg.global_every):
                sub = jax.tree_util.tree_map(lambda a: a[j], gp)
                kind = "full" if j == cfg.global_every - 1 else "window"
                x = layer_fn(sub, cfg, x, positions, kind, train)
            return x, None
        x, _ = jax.lax.scan(group_body, x, params["groups"])
    else:
        kind = "window" if cfg.attn_type == "swa" else "full"

        def body(x, lp):
            return layer_fn(lp, cfg, x, positions, kind, train), None
        x, _ = jax.lax.scan(body, x, params["layers"])

    if cfg.spiking is not None:
        x = x.mean(axis=0)  # rate decoding over T_s
    x = nn.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = nn.unembed(params["embed"], x)
    else:
        logits = nn.linear(params["lm_head"], x).astype(jnp.float32)
    logits = constrain(logits, "batch", "seq", "vocab")
    return logits, {}


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------


def _cache_len(cfg: ModelConfig, kind: str, max_len: int,
               headroom: int = 0) -> int:
    """Ring length for a cache of this kind. ``headroom`` (chunked
    prefill) widens window rings by up to chunk-1 extra slots: a C-token
    bite is scattered *before* attention runs, and with a bare
    ``window``-long ring its later writes would evict entries still
    inside earlier in-bite queries' windows (write at pos p+i lands on
    the slot holding p+i-s_len, which query p+j needs iff
    p+i-s_len > p+j-window — impossible once s_len >= window + C - 1)."""
    if kind != "window":
        return max_len
    return min(cfg.window + headroom, max_len)


def _packed_kv(cfg: ModelConfig) -> bool:
    """Spiking decode caches store K/V bit-packed (uint32 words) when the
    config's engine asks for it — the paper's 32x spike-RAM compression
    (byte-level SRAM dataflow) carried to the serve path. Cache layout is
    static per config, so this reads ``cfg.engine`` directly rather than
    the ambient engine."""
    return (cfg.spiking is not None and cfg.engine is not None
            and cfg.engine.packed_kv)


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int,
               batch=None, params=None,
               chunk_headroom: int = 0) -> Dict[str, Any]:
    """``chunk_headroom``: extra ring slots for window caches when decode
    will be fed chunked-prefill bites wider than one token (pass
    max_chunk - 1; see _cache_len)."""
    dt = jnp.dtype(cfg.dtype)
    b = batch_size * (cfg.spiking.time_steps if cfg.spiking else 1)
    packed = _packed_kv(cfg)
    words = -(-cfg.head_dim // 32)

    def kv(n_layers, kind):
        s = _cache_len(cfg, kind, max_len, chunk_headroom)
        # validity tags carry a batch (slot) dimension: every slot has its
        # own timeline, so continuous batching can hold sequences at
        # different positions in the same cache (the serve orchestrator's
        # per-slot state; a freed slot is re-admitted with all tags -1)
        if packed:
            shape = (n_layers, b, s, cfg.num_kv_heads, words)
            return {"k": jnp.zeros(shape, jnp.uint32),
                    "v": jnp.zeros(shape, jnp.uint32),
                    "pos": jnp.full((n_layers, batch_size, s), -1, jnp.int32)}
        return {
            "k": jnp.zeros((n_layers, b, s, cfg.num_kv_heads, cfg.head_dim), dt),
            "v": jnp.zeros((n_layers, b, s, cfg.num_kv_heads, cfg.head_dim), dt),
            "pos": jnp.full((n_layers, batch_size, s), -1, jnp.int32),
        }

    if cfg.attn_type == "local_global":
        g = cfg.num_layers // cfg.global_every
        return {"local": kv(g * (cfg.global_every - 1), "window"),
                "global": kv(g, "full")}
    kind = "window" if cfg.attn_type == "swa" else "full"
    return {"layers": kv(cfg.num_layers, kind)}


def _scatter_rows(cache, new, slots):
    """Per-row cache write: cache (B', S, ...), new (B', C, ...), slots
    (B', C) int32 — row b writes new[b, i] at cache[b, slots[b, i]].
    Out-of-range slot indices (== S, the padding sentinel) are dropped, so
    padded chunk positions never touch the cache."""
    return jax.vmap(lambda c, n, s: c.at[s].set(n, mode="drop"))(
        cache, new, slots)


def _decode_layer(p, cfg: ModelConfig, x, cache_l, pos, n_tok, kind: str):
    """One decode token or a chunked-prefill bite against this layer's KV
    cache, with a *per-slot* timeline.

    x: (B', C, D) — B' = B (dense) or T_s*B (spiking, time-major fold);
    cache_l: {'k','v','pos'} for this layer, pos tags shaped (B, S);
    pos: (B,) absolute position of x[:, 0] per slot;
    n_tok: (B,) count of real tokens per slot (rows are right-padded to
    the common chunk width C; padded positions are neither written to the
    cache nor tagged valid, so a decode slot rides a prefill wave at C=1
    cost in cache state).
    """
    b = pos.shape[0]
    b_rows, c = x.shape[0], x.shape[1]
    reps_t = b_rows // b                       # T_s in spiking mode, else 1
    tile = (lambda u: jnp.tile(u, (reps_t,) + (1,) * (u.ndim - 1))) \
        if reps_t > 1 else (lambda u: u)
    qpos = pos[:, None] + jnp.arange(c)        # (B, C) absolute q positions
    qpos_rows = tile(qpos)                     # (B', C)
    h = nn.rmsnorm(p["ln1"], x, cfg.norm_eps)
    q, k, v = _project_qkv(p, cfg, h, qpos_rows)
    if cfg.spiking is not None:
        # T_s is folded into the batch dim; unfold for LIF dynamics over time.
        t = cfg.spiking.time_steps

        def lif_t(u):
            u_t = u.reshape(t, -1, *u.shape[1:])
            s, _ = lif_scan(u_t, cfg.spiking)
            return s.reshape(-1, *u.shape[1:])
        q, k, v = lif_t(q), lif_t(k), lif_t(v)
    else:
        lif_t = None
    window = cfg.window if kind == "window" else None
    packed = _packed_kv(cfg)
    if packed:
        # spikes pack losslessly: K/V are {0,1} after the LIF, one uint32
        # word per 32 channels (the binary engine's spike-RAM layout)
        k, v = pack_bits(k), pack_bits(v)
    s_len = cache_l["k"].shape[1]
    # rolling write for window caches (== pos for full); chunk width must
    # not exceed the window, or a bite would overwrite its own entries
    slot = jnp.where(jnp.arange(c)[None, :] < n_tok[:, None],
                     qpos % s_len, s_len).astype(jnp.int32)  # (B, C)
    slot_rows = tile(slot)
    k_cache = _scatter_rows(cache_l["k"], k, slot_rows)
    v_cache = _scatter_rows(cache_l["v"], v, slot_rows)
    entry_pos = jax.vmap(lambda e, s, val: e.at[s].set(val, mode="drop"))(
        cache_l["pos"], slot, qpos.astype(jnp.int32))
    if cfg.spiking is not None:
        qf = q.reshape(b_rows, c, cfg.num_kv_heads,
                       cfg.num_heads // cfg.num_kv_heads, cfg.head_dim)
        if packed:
            # AND-PopCount against the packed cache: exact integer overlap
            # counts, bit-identical to the fp32 dot on unpacked spikes
            qp = pack_bits(qf)                       # (B', C, KH, rep, W)
            kcT = k_cache.transpose(0, 2, 1, 3)      # (B', KH, S, W)
            counts = jax.lax.population_count(
                qp[:, :, :, :, None, :] & kcT[:, None, :, None, :, :]).sum(
                axis=-1).astype(jnp.int32)           # (B', C, KH, rep, S)
            sc = counts.astype(jnp.float32) / math.sqrt(cfg.head_dim)
        else:
            sc = jnp.einsum("bcgrd,bkgd->bcgrk", qf.astype(jnp.float32),
                            k_cache.astype(jnp.float32)) / math.sqrt(cfg.head_dim)
        a = binarize(sc, p["delta"], cfg.spiking.surrogate_alpha)
        valid = (entry_pos[:, None, :] >= 0) & \
            (entry_pos[:, None, :] <= qpos[:, :, None])       # (B, C, S)
        if window is not None:
            valid &= entry_pos[:, None, :] > qpos[:, :, None] - window
        a = jnp.where(tile(valid)[:, :, None, None, :], a, 0.0)
        vc = unpack_bits(v_cache, cfg.head_dim) if packed \
            else v_cache.astype(jnp.float32)
        attn = jnp.einsum("bcgrk,bkgd->bcgrd", a, vc)
        attn = attn.reshape(b_rows, c, cfg.q_dim).astype(x.dtype)
    else:
        attn = nn.decode_attention(q, k_cache, v_cache, entry_pos=entry_pos,
                                   cur_pos=qpos, window=window)
        attn = attn.reshape(b_rows, c, cfg.q_dim)
    x = x + nn.linear(p["wo"], attn)
    h2 = nn.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.spiking is not None:
        # mirror the full-seq spiking MLP (up -> LIF -> down, no gate/act)
        # so decode stays consistent with prefill token-for-token
        up = nn.linear(p["mlp"]["up"], h2)
        x = x + nn.linear(p["mlp"]["down"], lif_t(up))
    else:
        x = x + nn.mlp(p["mlp"], h2, cfg.act)
    new_cache = {"k": k_cache, "v": v_cache, "pos": entry_pos}
    return x, new_cache


def decode_step(params, cfg: ModelConfig, cache, tokens, pos, n_tok=None):
    """tokens: (B, C) int32 — one decode token per slot (C == 1) or a
    chunked-prefill bite; pos: scalar or (B,) int32, the absolute position
    of tokens[:, 0] per slot (a scalar broadcasts: all slots aligned, the
    pre-orchestrator contract); n_tok: optional (B,) count of real tokens
    per row when rows are right-padded to the common chunk width C.

    Returns (logits (B, C, V), new_cache).
    """
    b, c = tokens.shape
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos, (b,))
    n_tok = jnp.full((b,), c, jnp.int32) if n_tok is None \
        else jnp.asarray(n_tok, jnp.int32)
    x = nn.embed(params["embed"], tokens)
    if cfg.spiking is not None:
        t = cfg.spiking.time_steps
        x = jnp.broadcast_to(x[None], (t,) + x.shape).reshape(-1, *x.shape[1:])
    x = constrain(x, "batch", None, "embed")

    if cfg.attn_type == "local_global":
        g = cfg.num_layers // cfg.global_every
        n_local = cfg.global_every - 1

        def group_body(x, inp):
            gp, c_loc, c_glob = inp
            new_loc, new_glob = [], []
            for j in range(cfg.global_every):
                sub = jax.tree_util.tree_map(lambda a: a[j], gp)
                if j < n_local:
                    cl = jax.tree_util.tree_map(lambda a: a[j], c_loc)
                    x, nc = _decode_layer(sub, cfg, x, cl, pos, n_tok,
                                          "window")
                    new_loc.append(nc)
                else:
                    cl = jax.tree_util.tree_map(lambda a: a[0], c_glob)
                    x, nc = _decode_layer(sub, cfg, x, cl, pos, n_tok,
                                          "full")
                    new_glob.append(nc)
            stack = lambda cs: jax.tree_util.tree_map(
                lambda *a: jnp.stack(a), *cs)
            return x, (stack(new_loc), stack(new_glob))

        resh = lambda c, n: jax.tree_util.tree_map(
            lambda a: a.reshape(g, n, *a.shape[1:]), c)
        x, (nl, ng) = jax.lax.scan(
            group_body, x,
            (params["groups"], resh(cache["local"], n_local),
             resh(cache["global"], 1)))
        flat = lambda c: jax.tree_util.tree_map(
            lambda a: a.reshape(-1, *a.shape[2:]), c)
        new_cache = {"local": flat(nl), "global": flat(ng)}
    else:
        kind = "window" if cfg.attn_type == "swa" else "full"

        def body(x, inp):
            lp, cl = inp
            x, nc = _decode_layer(lp, cfg, x, cl, pos, n_tok, kind)
            return x, nc
        x, new_layers = jax.lax.scan(body, x,
                                     (params["layers"], cache["layers"]))
        new_cache = {"layers": new_layers}

    if cfg.spiking is not None:
        t = cfg.spiking.time_steps
        x = x.reshape(t, -1, *x.shape[1:]).mean(axis=0)
    x = nn.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = nn.unembed(params["embed"], x)
    else:
        logits = nn.linear(params["lm_head"], x).astype(jnp.float32)
    return logits, new_cache


def invalidate_slots(cache, slot_mask):
    """Free masked slots for re-admission: every validity tag of a masked
    slot goes to -1, so the next occupant starts at position 0 attending
    over nothing — the previous request's K/V rows become unreachable
    (they are overwritten as the new sequence advances).

    slot_mask: (B,) bool. K/V payloads are left in place (tags alone gate
    attention), which keeps this a cheap tag-only write.
    """
    def fix(path, leaf):
        if getattr(path[-1], "key", None) == "pos":
            return jnp.where(slot_mask[None, :, None],
                             jnp.int32(-1), leaf)
        return leaf
    return jax.tree_util.tree_map_with_path(fix, cache)
