"""h2o-danube-3-4b [dense]: 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000 — llama+mistral mix, sliding-window attention
[arXiv:2401.16818]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", family="dense",
    num_layers=24, d_model=3840, num_heads=32, num_kv_heads=8, head_dim=120,
    d_ff=10240, vocab_size=32000,
    attn_type="swa", window=4096, act="silu", gated=True,
    rope_theta=10000.0,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=96, num_heads=4, num_kv_heads=2, head_dim=24,
    d_ff=192, vocab_size=512, window=16, dtype="float32", remat=False)
