"""Serving orchestrator correctness (launch/serve.py).

Pins the continuous-batching contract: slot reuse is isolated (a request
admitted into a freed slot decodes from position 0 over an invalidated
cache — the tentpole bugfix), staggered admission is bitwise-equal to
running each request alone, chunked prefill matches whole-prompt prefill,
and retirement uses the full cache capacity. The mesh-sharded server is
exercised in a subprocess with a forced 8-device host platform.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch import steps as steps_lib
from repro.launch.serve import BatchedServer, Request, choose_chunk
from repro.models import registry

ARCHS = ["h2o-danube-3-4b", "spikingformer-lm"]


def _params(cfg):
    return registry.init(cfg, jax.random.PRNGKey(0))


def _prompt(cfg, n, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, n).astype(np.int32)


def _serve(cfg, params, reqs, *, slots, max_len=32, chunk=0):
    server = BatchedServer(cfg, params, slots, max_len, chunk=chunk,
                           trace_logits=True)
    for r in reqs:
        server.submit(r)
    server.run()
    assert len(server.completed) == len(reqs)
    return {r.rid: r for r in server.completed}


def _req(rid, prompt, max_new):
    return Request(rid=rid, prompt=prompt, max_new_tokens=max_new)


# ---------------------------------------------------------------------------
# slot reuse isolation (the tentpole regression)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ARCHS)
def test_slot_reuse_isolated_from_previous_occupant(arch):
    """slots=1: a short and a long request share the single slot back to
    back; each produces logits bitwise-equal to running alone."""
    cfg = get_config(arch, smoke=True)
    params = _params(cfg)
    mk = lambda: [_req(0, _prompt(cfg, 6, 1), 3),
                  _req(1, _prompt(cfg, 9, 2), 5)]
    shared = _serve(cfg, params, mk(), slots=1)
    for proto in mk():
        solo = _serve(cfg, params, [_req(proto.rid, proto.prompt,
                                         proto.max_new_tokens)], slots=1)
        assert shared[proto.rid].generated == solo[proto.rid].generated
        for a, b in zip(shared[proto.rid].logit_trace,
                        solo[proto.rid].logit_trace):
            np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("arch", ARCHS)
def test_slot_reuse_regression_vs_shared_counter_semantics(arch):
    """Demonstrates the fixed bug. Old behavior: one shared scalar pos, no
    per-slot validity tags — a request admitted into a freed slot was
    decoded at the previous occupant's position over its stale K/V. Replay
    that semantics directly on a dirty cache and confirm it corrupts the
    logits; the orchestrator (per-slot pos + invalidation at admission)
    matches the clean single-request reference instead."""
    cfg = get_config(arch, smoke=True)
    params = _params(cfg)
    prompt_a, prompt_b = _prompt(cfg, 8, 3), _prompt(cfg, 5, 4)
    step = jax.jit(steps_lib.build_serve_step(cfg))

    # request A occupies the slot for 8 positions
    cache = registry.init_cache(cfg, 1, 32)
    for i in range(len(prompt_a)):
        _, cache = step(params, cache, jnp.asarray([[prompt_a[i]]]),
                        jnp.asarray(i, jnp.int32))
    # clean reference for B: fresh cache, positions from 0
    ref_cache = registry.init_cache(cfg, 1, 32)
    ref = []
    for i in range(len(prompt_b)):
        lg, ref_cache = step(params, ref_cache,
                             jnp.asarray([[prompt_b[i]]]),
                             jnp.asarray(i, jnp.int32))
        ref.append(np.asarray(lg[0, 0]))

    # OLD semantics: B decodes in A's slot at A's continuation positions,
    # attending over A's stale entries -> logits differ from the reference
    old_cache, old = cache, []
    for i in range(len(prompt_b)):
        lg, old_cache = step(params, old_cache,
                             jnp.asarray([[prompt_b[i]]]),
                             jnp.asarray(len(prompt_a) + i, jnp.int32))
        old.append(np.asarray(lg[0, 0]))
    assert any(not np.array_equal(o, r) for o, r in zip(old, ref)), \
        "stale-slot replay unexpectedly matched the clean reference"

    # NEW semantics: the orchestrator re-admits the slot with invalidated
    # tags and decodes B from position 0 -> bitwise-equal to the reference
    shared = _serve(cfg, params,
                    [_req(0, prompt_a, 2), _req(1, prompt_b, 3)], slots=1)
    solo = _serve(cfg, params, [_req(1, prompt_b, 3)], slots=1)
    assert shared[1].generated == solo[1].generated
    for a, b in zip(shared[1].logit_trace, solo[1].logit_trace):
        np.testing.assert_array_equal(a, b)


def test_invalidate_slots_resets_only_masked_slot():
    cfg = get_config("h2o-danube-3-4b", smoke=True)
    params = _params(cfg)
    cache = registry.init_cache(cfg, 2, 16)
    step = jax.jit(steps_lib.build_batched_serve_step(cfg))
    toks = jnp.asarray(_prompt(cfg, 4, 0)).reshape(2, 2)
    _, cache = step(params, cache, toks, jnp.zeros(2, jnp.int32),
                    jnp.full(2, 2, jnp.int32))
    tags = np.asarray(cache["layers"]["pos"])
    assert (tags[:, :, :2] >= 0).all()
    cache2 = registry.invalidate_slots(cfg, cache,
                                       jnp.asarray([True, False]))
    tags2 = np.asarray(cache2["layers"]["pos"])
    assert (tags2[:, 0] == -1).all()
    np.testing.assert_array_equal(tags2[:, 1], tags[:, 1])


# ---------------------------------------------------------------------------
# staggered admission
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ARCHS)
def test_staggered_admission_matches_sequential_reference(arch):
    """Three requests with different prompt lengths over two slots: the
    third is admitted mid-flight while the survivors keep decoding. Every
    request's sampled tokens and logit rows are bitwise-equal to its
    single-request sequential run."""
    cfg = get_config(arch, smoke=True)
    params = _params(cfg)
    mk = lambda: [_req(0, _prompt(cfg, 7, 5), 4),
                  _req(1, _prompt(cfg, 4, 6), 6),
                  _req(2, _prompt(cfg, 10, 7), 3)]
    shared = _serve(cfg, params, mk(), slots=2)
    for proto in mk():
        solo = _serve(cfg, params, [_req(proto.rid, proto.prompt,
                                         proto.max_new_tokens)], slots=1)
        assert shared[proto.rid].generated == solo[proto.rid].generated
        for a, b in zip(shared[proto.rid].logit_trace,
                        solo[proto.rid].logit_trace):
            np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# chunked prefill
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ARCHS)
def test_chunked_prefill_matches_whole_prompt_prefill(arch):
    """The first sampled logits row (the one conditioned on the whole
    prompt) agrees with build_prefill_step's last-position logits, for
    every chunk width; and all chunk widths agree with each other
    bitwise."""
    cfg = get_config(arch, smoke=True)
    params = _params(cfg)
    prompt = _prompt(cfg, 11, 8)
    prefill = jax.jit(steps_lib.build_prefill_step(cfg))
    want = np.asarray(prefill(params, {"tokens": jnp.asarray(prompt)[None]})
                      )[0, -1]
    rows = []
    for chunk in (1, 4, 16):
        got = _serve(cfg, params, [_req(0, prompt, 2)], slots=1,
                     chunk=chunk)
        rows.append(got[0].logit_trace[0])
        np.testing.assert_allclose(rows[-1], want, atol=2e-4, rtol=2e-4)
    for r in rows[1:]:
        np.testing.assert_array_equal(rows[0], r)


def test_chunked_prefill_beyond_window_matches_tokenwise():
    """Rolling-window regression: with a prompt longer than the attention
    window, a prefill bite's scatter runs before attention — without ring
    headroom its later writes evict entries still inside earlier in-bite
    queries' windows. The window cache carries chunk-1 extra slots, so
    every chunk width stays bitwise-equal to token-at-a-time prefill."""
    cfg = get_config("h2o-danube-3-4b", smoke=True)
    assert cfg.attn_type == "swa" and cfg.window == 16
    params = _params(cfg)
    prompt = _prompt(cfg, 30, 10)       # prompt >> window
    runs = {}
    for chunk in (1, 8, 16):
        got = _serve(cfg, params, [_req(0, prompt, 4)], slots=1,
                     max_len=48, chunk=chunk)
        runs[chunk] = got[0]
    for chunk in (8, 16):
        assert runs[chunk].generated == runs[1].generated, chunk
        # ring length is window + chunk - 1, so the softmax reduction
        # order differs across chunk widths — tokens must match exactly,
        # logits to fp32 reduction tolerance
        for a, b in zip(runs[chunk].logit_trace, runs[1].logit_trace):
            np.testing.assert_allclose(a, b, atol=2e-4, rtol=2e-4)


def test_submit_rejects_degenerate_prompts():
    cfg = get_config("h2o-danube-3-4b", smoke=True)
    server = BatchedServer(cfg, _params(cfg), 1, 16)
    with pytest.raises(ValueError, match="empty"):
        server.submit(_req(0, np.zeros(0, np.int32), 2))
    with pytest.raises(ValueError, match="capacity"):
        server.submit(_req(1, _prompt(cfg, 17, 0), 2))
    with pytest.raises(ValueError, match="max_new_tokens"):
        server.submit(_req(2, _prompt(cfg, 4, 0), 0))


def test_chunk_policy_follows_decode_share():
    """choose_chunk: Eq. 6 argmax widens (never narrows) as the decode
    share of the batch grows, returns 1 with no backlog, and respects the
    cap."""
    assert choose_chunk(0, 3, 32) == 1
    widths = [choose_chunk(64, n_dec, 32) for n_dec in range(4)]
    assert all(b >= a for a, b in zip(widths, widths[1:]))
    assert widths[-1] > widths[0]
    assert all(1 <= w <= 32 for w in widths)
    assert choose_chunk(64, 8, 4) <= 4


# ---------------------------------------------------------------------------
# retirement / capacity
# ---------------------------------------------------------------------------


def test_retirement_uses_full_cache_capacity():
    """A request bounded only by cache capacity generates max_len - L + 1
    tokens: positions 0..max_len-1 all hold written entries, plus the
    final sampled token that is never written back (the old `>= max_len-1`
    check retired one usable position early)."""
    cfg = get_config("h2o-danube-3-4b", smoke=True)
    params = _params(cfg)
    max_len, plen = 16, 10
    got = _serve(cfg, params, [_req(0, _prompt(cfg, plen, 9), 100)],
                 slots=1, max_len=max_len)
    assert len(got[0].generated) == max_len - plen + 1


def test_kv_cache_stats_selects_by_key():
    """Footprint counts exactly the k/v payload bytes (selected by key),
    never the validity tags — whatever their dtype."""
    for arch, packed in (("h2o-danube-3-4b", False),
                         ("spikingformer-lm", True)):
        cfg = get_config(arch, smoke=True)
        server = BatchedServer(cfg, _params(cfg), 2, 16)
        stats = server.kv_cache_stats()
        flat, _ = jax.tree_util.tree_flatten_with_path(server.cache)
        want = sum(l.nbytes for path, l in flat
                   if path[-1].key in ("k", "v"))
        assert stats["kv_bytes"] == want
        assert stats["packed"] is packed
        if packed:   # head_dim=16 spikes in one fp32-replacing uint32 word
            assert stats["compression"] == 16.0


def test_rejects_unslotted_family():
    cfg = get_config("rwkv6-3b", smoke=True)
    with pytest.raises(ValueError, match="slot"):
        BatchedServer(cfg, _params(cfg), 2, 16)


# ---------------------------------------------------------------------------
# mesh-sharded decode (subprocess: needs a forced 8-device host platform)
# ---------------------------------------------------------------------------

_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import jax, numpy as np
    from repro.configs import get_config
    from repro.launch.mesh import make_serve_mesh
    from repro.launch.serve import BatchedServer, Request
    from repro.models import registry

    assert len(jax.devices()) == 8
    cfg = get_config("{arch}", smoke=True)
    params = registry.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = lambda: [Request(rid=i, prompt=rng2, max_new_tokens=4)
                    for i, rng2 in enumerate(
                        rng.integers(0, cfg.vocab_size, (5, 7))
                        .astype(np.int32))]
    runs = {{}}
    for name, mesh in (("none", None), ("2x2", make_serve_mesh(2, 2)),
                       ("4x2", make_serve_mesh(4, 2))):
        server = BatchedServer(cfg, params, 4, 24, mesh=mesh)
        for r in reqs():
            server.submit(r)
        server.run()
        assert len(server.completed) == 5
        runs[name] = {{r.rid: r.generated for r in server.completed}}
        rng = np.random.default_rng(0)   # same prompts every run
    assert runs["2x2"] == runs["none"], (runs["2x2"], runs["none"])
    assert runs["4x2"] == runs["none"], (runs["4x2"], runs["none"])
    print("MESH-OK")
""")


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_mesh_sharded_server_matches_unsharded(arch):
    """BatchedServer under (data, model) serving meshes on 8 forced host
    devices: sharded cache/params, identical generations."""
    env = dict(os.environ,
               PYTHONPATH="src" + os.pathsep + os.environ.get(
                   "PYTHONPATH", ""))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _MESH_SCRIPT.format(arch=arch)],
        capture_output=True, text=True, timeout=900, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    assert "MESH-OK" in out.stdout
