"""Chunk-parallel WKV == per-token scan (§Perf R1 exactness)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container has no hypothesis; use fixed-seed shim
    from _propcheck import given, settings, strategies as st

from repro.models.rwkv import _wkv_chunked, _wkv_scan


def _inputs(seed, b, s, h, n, w_lo, w_hi):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    r = jax.random.normal(ks[0], (b, s, h, n))
    k = jax.random.normal(ks[1], (b, s, h, n))
    v = jax.random.normal(ks[2], (b, s, h, n))
    # RWKV6 decay parameterization: w = exp(-exp(x))
    w = jnp.exp(-jnp.exp(jax.random.uniform(ks[3], (b, s, h, n),
                                            minval=w_lo, maxval=w_hi)))
    u = jax.random.normal(ks[4], (h, n)) * 0.1
    return r, k, v, w, u


@pytest.mark.slow
@settings(max_examples=12, deadline=None)
@given(st.integers(0, 100), st.sampled_from([8, 16, 32]),
       st.integers(17, 80))
def test_chunked_matches_scan_realistic_decay(seed, chunk, s):
    r, k, v, w, u = _inputs(seed, 2, s, 2, 16, -5.0, -0.5)
    s0 = jnp.zeros((2, 2, 16, 16))
    y1, st1 = _wkv_scan(r, k, v, w, u, s0)
    y2, st2 = _wkv_chunked(r, k, v, w, u, s0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2),
                               atol=5e-4, rtol=5e-4)


def test_chunked_carries_state_across_calls():
    r, k, v, w, u = _inputs(0, 1, 64, 2, 16, -5.0, -1.0)
    s0 = jnp.zeros((1, 2, 16, 16))
    y_full, st_full = _wkv_chunked(r, k, v, w, u, s0, chunk=16)
    y1, st1 = _wkv_chunked(r[:, :32], k[:, :32], v[:, :32], w[:, :32], u,
                           s0, chunk=16)
    y2, st2 = _wkv_chunked(r[:, 32:], k[:, 32:], v[:, 32:], w[:, 32:], u,
                           st1, chunk=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                               atol=1e-4, rtol=1e-4)


def test_chunked_harsh_decay_state_still_exact():
    """Pathological decays distort only intra-chunk far-past terms (the
    clamp); the carried STATE stays exact (exponents <= 0 on that path)."""
    r, k, v, w, u = _inputs(3, 2, 64, 2, 16, -1.0, 2.0)
    s0 = jnp.zeros((2, 2, 16, 16))
    _, st1 = _wkv_scan(r, k, v, w, u, s0)
    _, st2 = _wkv_chunked(r, k, v, w, u, s0, chunk=32)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2),
                               atol=1e-4, rtol=1e-3)


def test_rwkv_forward_chunk_flag_equivalence():
    from repro.configs import get_config
    from repro.models import rwkv as R
    cfg = get_config("rwkv6-3b", smoke=True)
    p = R.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 40), 0, 512)
    l1, _ = R.forward(p, cfg, {"tokens": toks})
    import dataclasses
    cfg2 = cfg.replace(rwkv=dataclasses.replace(cfg.rwkv, wkv_chunk=16))
    l2, _ = R.forward(p, cfg2, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               atol=2e-3, rtol=2e-3)
