"""Fine-grained sparse-decoder datapath: gather-compacted spike matmul.

The third sparse-engine mode (DESIGN.md §9). The tile kernel
(``spike_matmul``) only skips *whole* (block_m x block_k) spike tiles, so
fine-grained or ragged sparsity — rows whose live channels are scattered
rather than coherently blocked, the regime FireFly-S shows dominates real
SNN activations — gets zero speedup there. This module is the
MXU-granularity translation of the paper's full sparse-decoder pipeline
(§IV-A): decode, dispatch only the touched weight rows, and balance the
load so no worker waits on the densest row.

  paper (FPGA)                      | here (TPU)
  ----------------------------------|----------------------------------
  M-lane carry-lookahead decode     | ``decode_indices``: cumsum
  (Eq. 5 propagate/generate chain   | prefix-compaction — the rank of
  extracts M nonzero indices/cycle) | each set bit IS the lane/cycle it
                                    | decodes in; pinned equivalent to
                                    | ``core.sparsity.
                                    | multilane_decode_full`` by test
  out-of-order weight dispatch      | the kernel gathers only the live
  (fetch only touched weight rows)  | weight rows ``w[idx]`` per
                                    | compacted chunk
  input tracker / load balancing    | ``build_schedule``: rows sorted by
  (no worker stalls on a dense      | occupancy into block_m groups,
  word)                             | each group's capacity rounded to a
                                    | pow2 bucket — every grid step in a
                                    | bucket does uniform work, steps
                                    | past a group's bucket are skipped

The contraction: ``y[m] = sum_i vals[m, i] * w[idx[m, i]]`` over the
compacted dim, fp32 (or int32) accumulation in compacted ascending-k
order, bias after the final accumulation — term-for-term the dense
reference on the live entries, so decoded-vs-dense is bitwise equal
whenever fp32 accumulation is order-exact (dyadic weights; same contract
as tile mode, pinned in tests/test_spike_decode.py). Carrying the
*values* (not just a live mask) makes the same kernel exact for the
binary-attention integer counts the wo projection consumes.

Off-TPU the kernels run in Pallas interpret mode (bit-exact lax
lowering). On TPU the in-kernel row gather ``w[idx]`` needs a
gather-capable Mosaic; ``sparse='tile'`` remains the conservative
datapath and ``auto`` only selects the decoded path from a concrete
occupancy histogram (DESIGN.md §9).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.bitpack import pad_to_multiple

# Crossover factor for ``sparse='auto'`` (DESIGN.md §9): a decoded MAC
# costs more than a tile MAC (row gather + batched matvec vs pure
# 128x128 MXU tiles), so the decoded path must cut modeled MACs by at
# least this factor below the tile path's before auto picks it.
DECODED_OVERHEAD = 2.0


def pow2ceil(x: jax.Array) -> jax.Array:
    """Elementwise smallest power of two >= x (0 -> 0, 1 -> 1). Integer
    bit-twiddling via ``lax.clz`` — no float log2 round-off."""
    x = x.astype(jnp.int32)
    p = 1 << (32 - jax.lax.clz(jnp.maximum(x, 1) - 1))
    return jnp.where(x <= 1, jnp.maximum(x, 0), p)


def decode_indices(s: jax.Array, cap: Optional[int] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    """Compact each row's non-zero K-indices by cumsum prefix-compaction.

    s: (M, K). Returns (idx (M, cap) int32, occ (M,) int32): ``idx[m,
    :occ[m]]`` are the positions of row m's non-zeros, ascending; padding
    slots hold 0 (masked by occ downstream). The rank ``cumsum(bits) - 1``
    of each set bit is exactly the slot the M-lane carry-lookahead decoder
    fires it in (lane ``rank % M`` of cycle ``rank // M``), so chunking
    ``idx`` by the lane count reproduces ``multilane_decode_full``'s
    per-cycle index sets — pinned by property test.

    ``cap`` (default K) statically bounds the compacted width; rows with
    more non-zeros than ``cap`` would be silently truncated, so concrete
    inputs are guarded (traced inputs trust the caller's bound).
    """
    m, k = s.shape
    bits = s != 0
    occ = bits.sum(-1).astype(jnp.int32)
    cap = k if cap is None else min(cap, k)
    if cap < k and not isinstance(occ, jax.core.Tracer):
        hi = int(jnp.max(occ)) if m else 0
        if hi > cap:
            raise ValueError(f"decode cap {cap} < max row occupancy {hi}")
    rank = jnp.cumsum(bits, axis=-1).astype(jnp.int32) - 1
    slot = jnp.where(bits, rank, cap)            # dead bits -> spill slot
    cols = jnp.broadcast_to(jnp.arange(k, dtype=jnp.int32)[None], (m, k))
    idx = jnp.zeros((m, cap + 1), jnp.int32).at[
        jnp.arange(m)[:, None], slot].set(cols, mode="drop")
    return idx[:, :cap], occ


def build_schedule(occ: jax.Array, block_m: int, c_block: int, cap: int):
    """Occupancy-binned load-balancing schedule (the OoO/weight-dispatch
    analog). Rows sort ascending by occupancy into ``block_m`` groups;
    each group's capacity is its max occupancy rounded up to a pow2
    bucket (clipped to the padded compacted width). Uniform work per
    bucket: a grid step is either fully live or skipped, so no tile
    waits on the densest row — the dense rows share a group.

    occ: (M,) per-row non-zero counts (M % block_m == 0 — pad first).
    Returns dict with ``order`` (ascending-occupancy row permutation),
    ``caps`` (n_groups,), per-group ``steps``, ``executed``/``total``
    c_block-step counts per N tile, and ``mac_fraction`` =
    executed/total (the decoded path's modeled MAC share vs a dense
    sweep of the compacted width). Mirrored bit-for-bit by the numpy
    twin ``sim.balance_sim.bucket_schedule`` (cross-validated in tests
    and benchmarks/dual_engine_bench.py).
    """
    m = occ.shape[0]
    assert m % block_m == 0, f"pad rows first: {m} % {block_m}"
    cp = max(c_block, -(-cap // c_block) * c_block)
    order = jnp.argsort(occ)                      # stable, ascending
    gmax = occ[order].reshape(m // block_m, block_m).max(axis=1)
    caps = jnp.minimum(pow2ceil(gmax), cp).astype(jnp.int32)
    steps = -(-caps // c_block)
    nc = cp // c_block
    executed = steps.sum()
    total = (m // block_m) * nc
    return {"order": order, "caps": caps, "steps": steps,
            "executed": executed, "total": total, "padded_cap": cp,
            "mac_fraction": executed / total}


def choose_sparse_path(s: jax.Array, block_m: int, block_k: int) -> str:
    """Per-call tile-vs-decoded decision from the concrete occupancy
    histogram (``sparse='auto'``, DESIGN.md §9). Tile skip wins at
    coherent sparsity (dark whole tiles), decoded wins at fine-grained /
    ragged sparsity (live tiles with few live rows); the crossover rule
    compares modeled MAC fractions with the decoded path handicapped by
    ``DECODED_OVERHEAD``.

    The occupancy reduction here is recomputed by the kernel's staging
    when 'decoded' wins — deliberate: the engine's custom-VJP static
    args can't carry arrays, the chooser only runs on eager (non-jit)
    calls, and the duplicated work is O(M*K), ~1/N of the matmul it
    gates.
    """
    from repro.kernels.spike_matmul import block_occupancy
    m, k = s.shape
    bm, bk = min(block_m, m), min(block_k, k)
    sp = pad_to_multiple(pad_to_multiple(s, 0, bm), 1, bk)
    tile_frac = float(block_occupancy(sp, bm, bk).mean())
    smp = pad_to_multiple(s, 0, bm)
    occ = (smp != 0).sum(-1).astype(jnp.int32)
    sched = build_schedule(occ, bm, bk, cap=k)
    dec_frac = float(sched["mac_fraction"]) * sched["padded_cap"] / max(k, 1)
    return "decoded" if dec_frac * DECODED_OVERHEAD < tile_frac else "tile"


# ---------------------------------------------------------------------------
# kernel bodies
# ---------------------------------------------------------------------------


def _gather_block(idx_ref, w_ref):
    """Gather the live weight rows of this compacted chunk: (block_m,
    c_block) indices into the (K, block_n) resident weight tile ->
    (block_m, c_block, block_n). This is the weight-dispatch stage — only
    touched rows enter the contraction."""
    return w_ref[...][idx_ref[...]]


def _contract(val_blk, gw, acc_dtype):
    """Batched row contraction on the compacted dim: (block_m, 1, c) x
    (block_m, c, block_n) -> (block_m, block_n)."""
    return jax.lax.dot_general(
        val_blk[:, None, :], gw, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=acc_dtype)[:, 0, :]


def _kernel(cap_ref, idx_ref, val_ref, w_ref, o_ref, *, c_block, nc):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(ci * c_block < cap_ref[0, 0])
    def _compute():
        gw = _gather_block(idx_ref, w_ref).astype(jnp.float32)
        o_ref[...] += _contract(val_ref[...].astype(jnp.float32), gw,
                                jnp.float32)


def _kernel_bias(cap_ref, idx_ref, val_ref, w_ref, b_ref, o_ref, *,
                 c_block, nc):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(ci * c_block < cap_ref[0, 0])
    def _compute():
        gw = _gather_block(idx_ref, w_ref).astype(jnp.float32)
        o_ref[...] += _contract(val_ref[...].astype(jnp.float32), gw,
                                jnp.float32)

    @pl.when(ci == nc - 1)
    def _bias():                      # after the final accumulation,
        o_ref[...] += b_ref[...].astype(jnp.float32)  # like the dense ref


def _qkernel(cap_ref, idx_ref, val_ref, w_ref, scale_ref, o_ref, acc_ref,
             *, c_block, nc):
    """Quantized decoded body: gathered int8 weight rows x spike/count
    lanes with an int32 VMEM accumulator; per-output-channel fp32 scale
    in the epilogue on the last grid step (which always executes — only
    the compute steps past a group's bucket are skipped)."""
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(ci * c_block < cap_ref[0, 0])
    def _compute():
        gw = _gather_block(idx_ref, w_ref)
        acc_ref[...] += _contract(val_ref[...], gw, jnp.int32)

    @pl.when(ci == nc - 1)
    def _epilogue():
        o_ref[...] = acc_ref[...].astype(jnp.float32) * \
            scale_ref[...].astype(jnp.float32)


def _qkernel_bias(cap_ref, idx_ref, val_ref, w_ref, scale_ref, b_ref,
                  o_ref, acc_ref, *, c_block, nc):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(ci * c_block < cap_ref[0, 0])
    def _compute():
        gw = _gather_block(idx_ref, w_ref)
        acc_ref[...] += _contract(val_ref[...], gw, jnp.int32)

    @pl.when(ci == nc - 1)
    def _epilogue():
        o_ref[...] = acc_ref[...].astype(jnp.float32) * \
            scale_ref[...].astype(jnp.float32) + \
            b_ref[...].astype(jnp.float32)


# ---------------------------------------------------------------------------
# staging shared by the fp32 and quantized entries
# ---------------------------------------------------------------------------


def _stage(s, block_m, c_block, cap):
    """Pad rows, decode + compact, sort by occupancy, build the bucket
    schedule. Returns (idx, vals, caps2d, order, schedule) with idx/vals
    already permuted into schedule order and padded to (Mp, Cp); vals
    carry the actual input values on live slots (1.0 for spikes, the
    integer counts for binary-attention contexts) and exact 0 elsewhere.
    """
    k = s.shape[1]
    sp = pad_to_multiple(s, 0, block_m)
    idx, occ = decode_indices(sp, cap=cap)
    sched = build_schedule(occ, block_m, c_block, cap=idx.shape[1])
    idx = pad_to_multiple(idx, 1, c_block)
    mask = jnp.arange(idx.shape[1], dtype=jnp.int32)[None] < occ[:, None]
    vals = jnp.where(mask, jnp.take_along_axis(sp, idx, axis=1), 0)
    order = sched["order"]
    caps2d = sched["caps"].reshape(-1, 1)
    return idx[order], vals[order], caps2d, order, sched


def _specs(block_m, block_n, c_block, kw):
    """(caps, idx, vals, w) block specs; weights stay fully K-resident
    per N tile so any row index in the chunk can be gathered."""
    return [
        pl.BlockSpec((1, 1), lambda gi, ni, ci: (gi, 0)),
        pl.BlockSpec((block_m, c_block), lambda gi, ni, ci: (gi, ci)),
        pl.BlockSpec((block_m, c_block), lambda gi, ni, ci: (gi, ci)),
        pl.BlockSpec((kw, block_n), lambda gi, ni, ci: (0, ni)),
    ]


def gather_spike_matmul(s: jax.Array, w: jax.Array, *,
                        bias: Optional[jax.Array] = None,
                        block_m: int = 128, block_n: int = 128,
                        c_block: int = 128, cap: Optional[int] = None,
                        interpret: Optional[bool] = None) -> jax.Array:
    """y = s @ w (+ bias) through the gather-compacted decoded datapath.

    s: (M, K) spikes (or sparse integer counts — values are carried, not
    assumed binary), w: (K, N) -> (M, N) fp32. Each row's non-zero
    K-indices are prefix-compacted on-device, rows are binned into pow2
    occupancy buckets (sorted into block_m groups), and the kernel
    contracts only the live weight rows — grid steps past a group's
    bucket capacity are skipped, so MACs scale with the *occupancy
    histogram*, not with K x the live-tile count.

    ``cap`` statically bounds the compacted width (default K: exact for
    any input, still skipping by bucket). Eager callers that know the
    max occupancy can pass a smaller cap to shrink the staged tensors.
    """
    m, k = s.shape
    k2, n = w.shape
    assert k == k2, f"spikes K={k} vs weight K={k2}"
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    c_block = min(c_block, k if cap is None else max(1, cap))

    idx, vals, caps2d, order, sched = _stage(s, block_m, c_block, cap)
    wp = pad_to_multiple(w, 1, block_n)
    mp, cp = idx.shape
    np_ = wp.shape[1]
    grid = (mp // block_m, np_ // block_n, cp // c_block)

    in_specs = _specs(block_m, block_n, c_block, k)
    operands = [caps2d, idx, vals.astype(jnp.float32), wp]
    if bias is None:
        kernel = functools.partial(_kernel, c_block=c_block, nc=grid[2])
    else:
        kernel = functools.partial(_kernel_bias, c_block=c_block,
                                   nc=grid[2])
        in_specs.append(pl.BlockSpec((1, block_n),
                                     lambda gi, ni, ci: (0, ni)))
        operands.append(pad_to_multiple(bias.reshape(1, n), 1, block_n))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n),
                               lambda gi, ni, ci: (gi, ni)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(*operands)
    return out[jnp.argsort(order)][:m, :n]


def slab_decode(s: jax.Array, *, l_block: int, c_block: int,
                cap: Optional[int] = None
                ) -> Tuple[jax.Array, jax.Array, jax.Array, int]:
    """Stage the decoded gather datapath for the fused layer kernel
    (``kernels/fused_layer``): per-(timestep, batch) slab row decode
    plus per-L-block pow2 occupancy-bucket caps.

    Unlike :func:`_stage`, rows are **not** permuted — the fused kernel
    consumes Q/K/V spikes in sequence order (the attention phases need
    them in place), so the bucket grouping is positional: each L-block
    of ``l_block`` consecutive rows gets capacity ``min(pow2ceil(max
    occupancy in block), padded width)``, and the kernel skips gather
    chunks past a block's cap. Dense rows cost their whole block its
    bucket (the price of skipping the load-balancing sort); the tile
    path has the same granularity, so decoded still only refines it.

    s: (T, B, L, K) spikes. Returns (idx (B, T, L, Cp) int32,
    vals (B, T, L, Cp) fp32, caps (B, T, ceil(L / l_block)) int32,
    c_block) with Cp a multiple of the (possibly clipped) c_block;
    index padding slots hold 0 and value padding slots exact 0.0, so
    over-gathering up to a cap is bitwise-free.
    """
    t, b, l, k = s.shape
    l_block = max(1, min(l_block, l))
    nlb = -(-l // l_block)
    flat = s.reshape(t * b * l, k)
    idx, occ = decode_indices(flat, cap=cap)
    c_block = max(1, min(c_block, idx.shape[1]))
    idx = pad_to_multiple(idx, 1, c_block)
    cp = idx.shape[1]
    mask = jnp.arange(cp, dtype=jnp.int32)[None] < occ[:, None]
    vals = jnp.where(mask, jnp.take_along_axis(flat, idx, axis=1), 0)
    occ_pad = pad_to_multiple(occ.reshape(t * b, l), 1, l_block)
    gmax = occ_pad.reshape(t * b, -1, l_block).max(axis=2)[:, :nlb]
    caps = jnp.minimum(pow2ceil(gmax), cp).astype(jnp.int32)
    idx = jnp.transpose(idx.reshape(t, b, l, cp), (1, 0, 2, 3))
    vals = jnp.transpose(vals.reshape(t, b, l, cp).astype(jnp.float32),
                         (1, 0, 2, 3))
    caps = jnp.transpose(caps.reshape(t, b, nlb), (1, 0, 2))
    return idx, vals, caps, c_block


def quant_gather_spike_matmul(s: jax.Array, qw: jax.Array,
                              scale: jax.Array, *,
                              bias: Optional[jax.Array] = None,
                              block_m: int = 128, block_n: int = 128,
                              c_block: int = 128,
                              cap: Optional[int] = None,
                              counts: bool = False,
                              interpret: Optional[bool] = None
                              ) -> jax.Array:
    """Decoded datapath against int8 weight codes: y = (s @ qw) * scale
    (+ bias), int32 accumulation over the gathered rows, per-channel
    scale in the epilogue — the same dual-side compression as
    ``quant_spike_matmul`` at compacted-row granularity. ``counts=True``
    rides the left operand on int32 lanes (binary-attention counts wrap
    int8 at 128); spikes stay int8.
    """
    m, k = s.shape
    k2, n = qw.shape
    assert k == k2, f"spikes K={k} vs weight K={k2}"
    assert qw.dtype == jnp.int8, f"quant kernel wants int8 codes, got " \
        f"{qw.dtype} (unpack int4 nibbles first)"
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    c_block = min(c_block, k if cap is None else max(1, cap))

    idx, vals, caps2d, order, sched = _stage(s, block_m, c_block, cap)
    wp = pad_to_multiple(qw, 1, block_n)
    mp, cp = idx.shape
    np_ = wp.shape[1]
    grid = (mp // block_m, np_ // block_n, cp // c_block)

    in_specs = _specs(block_m, block_n, c_block, k)
    in_specs.append(pl.BlockSpec((1, block_n),
                                 lambda gi, ni, ci: (0, ni)))
    operands = [caps2d, idx,
                vals.astype(jnp.int32 if counts else jnp.int8), wp,
                pad_to_multiple(scale.reshape(1, n).astype(jnp.float32),
                                1, block_n)]
    if bias is None:
        kernel = functools.partial(_qkernel, c_block=c_block, nc=grid[2])
    else:
        kernel = functools.partial(_qkernel_bias, c_block=c_block,
                                   nc=grid[2])
        in_specs.append(pl.BlockSpec((1, block_n),
                                     lambda gi, ni, ci: (0, ni)))
        operands.append(pad_to_multiple(
            bias.reshape(1, n).astype(jnp.float32), 1, block_n))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n),
                               lambda gi, ni, ci: (gi, ni)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        interpret=interpret,
    )(*operands)
    return out[jnp.argsort(order)][:m, :n]
