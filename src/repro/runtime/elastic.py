"""Elastic scaling: re-mesh a checkpoint onto a different device topology.

Checkpoints store full logical arrays (checkpoint/manager.py), so elastic
restore is a sharding re-assignment: build the target mesh's NamedSharding
tree from the same path-pattern rules and device_put. This covers
  * scale-up   (16x16 -> 2x16x16: new pod joins),
  * scale-down (drop a failed slice and continue data-parallel-narrower),
  * topology changes (data<->model reshape) as long as divisibility holds.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.sharding import filter_spec_for_mesh


def reshard_tree(tree, specs, mesh: Mesh):
    """device_put every leaf against `mesh` using its PartitionSpec."""
    def put(leaf, spec):
        spec = filter_spec_for_mesh(spec, mesh)
        return jax.device_put(leaf, NamedSharding(mesh, spec))
    return jax.tree_util.tree_map(put, tree, specs,
                                  is_leaf=lambda x: isinstance(x, P))


def elastic_restore_plan(old_mesh_shape, new_mesh_shape, global_batch: int):
    """Validate an elastic transition and return the new data-parallel
    layout (per-shard batch, #shards). Raises if the transition is
    impossible without changing global batch semantics."""
    old_dp = 1
    for n in old_mesh_shape.get("data", (1,)) if isinstance(
            old_mesh_shape.get("data"), tuple) else (old_mesh_shape.get("data", 1),):
        old_dp *= n
    new_dp = new_mesh_shape.get("data", 1) * new_mesh_shape.get("pod", 1)
    if global_batch % new_dp:
        raise ValueError(
            f"global batch {global_batch} not divisible by new DP degree "
            f"{new_dp}; adjust batch or use grad accumulation")
    return {"dp_degree": new_dp, "per_shard_batch": global_batch // new_dp,
            "grad_accum": 1}
