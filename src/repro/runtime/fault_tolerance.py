"""Fault tolerance for long multi-pod runs.

Pieces (single-controller implementations of multi-host policies):

* ``FailureInjector``   — deterministic pseudo-random failure injection
                          (chaos testing of the restart path);
* ``TrainSupervisor``   — runs the step function under a retry policy:
                          on failure, restore from the latest checkpoint
                          and replay the data stream (deterministic
                          pipeline => bit-identical recovery);
* ``StragglerMonitor``  — per-step wall-time EWMA; steps slower than
                          ``threshold x`` EWMA are flagged; the mitigation
                          hook (e.g. evict/re-pair a slow host, re-shard)
                          is invoked with the offending step record.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import numpy as np


class SimulatedFailure(RuntimeError):
    """Injected failure (stands in for a lost TPU worker / ICI timeout)."""


class FailureInjector:
    def __init__(self, rate: float = 0.0, seed: int = 0,
                 failure_steps: Optional[List[int]] = None):
        self.rate = rate
        self.rng = np.random.default_rng(seed)
        self.forced = set(failure_steps or [])
        self.injected: List[int] = []

    def maybe_fail(self, step: int):
        if step in self.forced or (self.rate > 0 and
                                   self.rng.random() < self.rate):
            if step not in self.injected:
                self.injected.append(step)
                raise SimulatedFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class StepRecord:
    step: int
    seconds: float
    flagged: bool


class StragglerMonitor:
    def __init__(self, threshold: float = 2.0, alpha: float = 0.1,
                 on_straggler: Optional[Callable[[StepRecord], None]] = None):
        self.threshold = threshold
        self.alpha = alpha
        self.ewma: Optional[float] = None
        self.records: List[StepRecord] = []
        self.on_straggler = on_straggler

    def observe(self, step: int, seconds: float) -> StepRecord:
        flagged = self.ewma is not None and \
            seconds > self.threshold * self.ewma
        rec = StepRecord(step, seconds, flagged)
        self.records.append(rec)
        if flagged and self.on_straggler:
            self.on_straggler(rec)
        if not flagged:  # don't poison the EWMA with outliers
            self.ewma = seconds if self.ewma is None else \
                (1 - self.alpha) * self.ewma + self.alpha * seconds
        return rec

    @property
    def straggler_steps(self) -> List[int]:
        return [r.step for r in self.records if r.flagged]


class TrainSupervisor:
    """Retry-from-checkpoint execution of a train loop.

    The caller provides ``run_segment(start_step) -> next_step`` which
    raises on failure after persisting progress via the checkpoint
    manager; the supervisor restores and resumes. ``max_restarts`` bounds
    the retry budget (a real deployment escalates after that).
    """

    def __init__(self, max_restarts: int = 3):
        self.max_restarts = max_restarts
        self.restarts: List[Dict[str, Any]] = []

    def run(self, run_segment: Callable[[int], int], start_step: int,
            total_steps: int) -> int:
        step = start_step
        while step < total_steps:
            try:
                step = run_segment(step)
            except SimulatedFailure as e:
                if len(self.restarts) >= self.max_restarts:
                    raise RuntimeError(
                        f"restart budget exhausted: {e}") from e
                self.restarts.append({"at_step": step, "error": str(e),
                                      "time": time.time()})
                # run_segment restores from the latest checkpoint itself;
                # we simply re-enter. step stays (segment re-reads ckpt).
        return step
