"""Spikingformer + CIFAR-Net — the paper's evaluated workloads (§V-A).

Spikingformer (arXiv:2304.11954) with binary attention (Shen et al. [17]):
SPS conv stem -> encoder blocks (SSA + MLP) -> classification head, with
*pre-neuron residuals* (membrane currents are added, spikes stay the only
conv/linear inputs — Table I's preferred high-accuracy/high-efficiency
combination, which is what FireFly-T accelerates).

CIFAR-Net: the spiking conv network of FireFly v2 (Table IV footnote 3).

Execution: activations carry a leading time axis (T, B, ...); every
Conv/Linear consumes spikes from a LIF neuron; BatchNorm carries running
stats through a `state` tree (threaded by the train loop).
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.spiking import SpikingConfig, binarize, lif_scan
from repro.parallel.sharding import constrain
from . import nn

# CIFAR-Net conv spec: (channels, pool) per layer; pool in {'', 'mp', 'ap'}
CIFARNET_SPEC: Tuple[Tuple[int, str], ...] = (
    (32, ""), (256, ""), (256, "mp"), (256, ""), (256, ""), (256, "mp"),
    (512, "mp"), (1024, "ap"))


def _sps_channels(cfg: ModelConfig) -> List[int]:
    d = cfg.d_model
    return [max(8, d // 8), max(8, d // 4), max(16, d // 2), d]


def _sps_pools(cfg: ModelConfig) -> List[bool]:
    n = 4
    stages = cfg.vision.sps_stages
    return [i >= n - stages for i in range(n)]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _block_init(key, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    ks = jax.random.split(key, 7)
    return {
        "wq": nn.linear_init(ks[0], d, cfg.q_dim, dtype=dt),
        "wk": nn.linear_init(ks[1], d, cfg.q_dim, dtype=dt),
        "wv": nn.linear_init(ks[2], d, cfg.q_dim, dtype=dt),
        "wo": nn.linear_init(ks[3], cfg.q_dim, d, dtype=dt),
        "bn_q": nn.batchnorm_init(cfg.q_dim, dt),
        "bn_k": nn.batchnorm_init(cfg.q_dim, dt),
        "bn_v": nn.batchnorm_init(cfg.q_dim, dt),
        "bn_o": nn.batchnorm_init(d, dt),
        "delta": jnp.asarray(cfg.spiking.attn_threshold_init, jnp.float32),
        "w1": nn.linear_init(ks[4], d, cfg.d_ff, dtype=dt),
        "bn_1": nn.batchnorm_init(cfg.d_ff, dt),
        "w2": nn.linear_init(ks[5], cfg.d_ff, d, dtype=dt),
        "bn_2": nn.batchnorm_init(d, dt),
    }


def _block_state(cfg: ModelConfig):
    return {"bn_q": nn.batchnorm_state_init(cfg.q_dim),
            "bn_k": nn.batchnorm_state_init(cfg.q_dim),
            "bn_v": nn.batchnorm_state_init(cfg.q_dim),
            "bn_o": nn.batchnorm_state_init(cfg.d_model),
            "bn_1": nn.batchnorm_state_init(cfg.d_ff),
            "bn_2": nn.batchnorm_state_init(cfg.d_model)}


def init(cfg: ModelConfig, key) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.dtype)
    if cfg.family == "cifarnet":
        return _init_cifarnet(cfg, key)
    ks = jax.random.split(key, 3 + 4)
    chans = [cfg.vision.in_channels] + _sps_channels(cfg)
    sps = []
    for i in range(4):
        sps.append({"conv": nn.conv2d_init(ks[i], chans[i], chans[i + 1],
                                           dtype=dt),
                    "bn": nn.batchnorm_init(chans[i + 1], dt)})
    keys = jax.random.split(ks[4], cfg.num_layers)
    return {
        "sps": sps,
        "blocks": jax.vmap(lambda k: _block_init(k, cfg))(keys),
        "head": nn.linear_init(ks[5], cfg.d_model, cfg.vocab_size, bias=True,
                               dtype=dt),
    }


def init_state(cfg: ModelConfig) -> Dict[str, Any]:
    if cfg.family == "cifarnet":
        return {"convs": [nn.batchnorm_state_init(c)
                          for c, _ in CIFARNET_SPEC]}
    chans = _sps_channels(cfg)
    stacked = jax.tree_util.tree_map(
        lambda *a: jnp.stack(a),
        *[_block_state(cfg) for _ in range(cfg.num_layers)])
    return {"sps": [nn.batchnorm_state_init(c) for c in chans],
            "blocks": stacked}


def _init_cifarnet(cfg: ModelConfig, key):
    dt = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, len(CIFARNET_SPEC) + 1)
    convs = []
    c_in = cfg.vision.in_channels
    for i, (c, _) in enumerate(CIFARNET_SPEC):
        convs.append({"conv": nn.conv2d_init(keys[i], c_in, c, dtype=dt),
                      "bn": nn.batchnorm_init(c, dt)})
        c_in = c
    return {"convs": convs,
            "head": nn.linear_init(keys[-1], c_in, cfg.vocab_size, bias=True,
                                   dtype=dt)}


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _lif(x, cfg: ModelConfig):
    s, _ = lif_scan(x, cfg.spiking)
    return s


def _fold_t(f, x, *args, **kw):
    """Apply f over (T*B, ...) by folding the time axis."""
    t = x.shape[0]
    y = f(x.reshape(-1, *x.shape[2:]), *args, **kw)
    return y.reshape(t, -1, *y.shape[1:])


def _sps(params, state, cfg: ModelConfig, images, train: bool):
    """images: (B, H, W, C) -> (tokens (T, B, L, D), new sps state)."""
    t = cfg.spiking.time_steps
    x = jnp.broadcast_to(images[None], (t,) + images.shape)  # direct coding
    pools = _sps_pools(cfg)
    new_state = []
    for i, p in enumerate(params["sps"]):
        x = _fold_t(lambda u: nn.conv2d(p["conv"], u), x)
        xf = x.reshape(-1, *x.shape[2:])
        yf, st = nn.batchnorm(p["bn"], state["sps"][i], xf, train=train)
        new_state.append(st)
        x = yf.reshape(x.shape)
        if i < len(params["sps"]) - 1:
            x = _lif(x, cfg)                     # spikes feed the next conv
        if pools[i]:
            x = _fold_t(nn.maxpool2, x)
    tt, b, h, w, d = x.shape
    return x.reshape(tt, b, h * w, d), new_state


def _block(p, st, cfg: ModelConfig, x, train: bool):
    """One encoder layer. x: (T,B,L,D) membrane currents.

    The whole layer program — input LIF + SSA bundle + wo/bn_o +
    pre-neuron residuals + spiking MLP — is owned by the engine
    (core.engine.layer_step): with ``overlap='fused' | 'pipeline'`` both
    overlay halves run as one Pallas grid spanning the layer (Fig. 5,
    with the MLP phases riding the same wavefront), otherwise the engine
    composes the sequential reference (which still hands the SSA bundle
    to ssa_step, so bundle-level fusion survives a layer-level
    fallback). The model keeps only the scan plumbing.
    """
    from repro.core.engine import layer_step
    return layer_step(p, st, cfg, x, train=train)


def forward(params, cfg: ModelConfig, batch, *, train: bool = False,
            state: Optional[Dict] = None):
    """batch: {'images': (B, H, W, C)} -> (logits (B, classes), aux)."""
    if cfg.family == "cifarnet":
        return _forward_cifarnet(params, cfg, batch, train=train, state=state)
    state = state if state is not None else init_state(cfg)
    x, sps_state = _sps(params, state, cfg, batch["images"], train)
    x = constrain(x, None, "batch", "seq", "embed")

    block_fn = _block
    if cfg.remat and train:
        block_fn = jax.checkpoint(_block, static_argnums=(2, 4),
                                  policy=jax.checkpoint_policies.nothing_saveable)

    def body(x, inp):
        bp, bst = inp
        x, new_bst = block_fn(bp, bst, cfg, x, train)
        return x, new_bst
    x, blocks_state = jax.lax.scan(body, x,
                                   (params["blocks"], state["blocks"]))
    spikes = _lif(x, cfg)
    rate = spikes.astype(jnp.float32).mean(axis=(0, 2))       # (B, D)
    logits = nn.linear(params["head"], rate.astype(x.dtype)).astype(jnp.float32)
    new_state = {"sps": sps_state, "blocks": blocks_state}
    fire_rate = spikes.astype(jnp.float32).mean()
    return logits, {"state": new_state, "fire_rate": fire_rate}


def _forward_cifarnet(params, cfg: ModelConfig, batch, *, train: bool,
                      state: Optional[Dict]):
    state = state if state is not None else init_state(cfg)
    t = cfg.spiking.time_steps
    images = batch["images"]
    x = jnp.broadcast_to(images[None], (t,) + images.shape)
    new_state = []
    for i, ((c, pool), p) in enumerate(zip(CIFARNET_SPEC, params["convs"])):
        x = _fold_t(lambda u: nn.conv2d(p["conv"], u), x)
        xf = x.reshape(-1, *x.shape[2:])
        yf, st = nn.batchnorm(p["bn"], state["convs"][i], xf, train=train)
        new_state.append(st)
        x = _lif(yf.reshape(x.shape), cfg)
        if pool == "mp":
            x = _fold_t(nn.maxpool2, x)
        elif pool == "ap":
            x = x.mean(axis=(2, 3))                            # (T, B, C)
    rate = x.astype(jnp.float32).mean(axis=0)                  # (B, C)
    logits = nn.linear(params["head"],
                       rate.astype(jnp.dtype(cfg.dtype))).astype(jnp.float32)
    return logits, {"state": {"convs": new_state},
                    "fire_rate": x.astype(jnp.float32).mean()}


def layer_sparsities(params, cfg: ModelConfig, batch, state=None):
    """Per-layer spike sparsity (Fig. 11 reproduction): returns a list of
    (layer_name, sparsity) measured on the given batch."""
    state = state if state is not None else init_state(cfg)
    out: List[Tuple[str, float]] = []
    if cfg.family == "cifarnet":
        t = cfg.spiking.time_steps
        x = jnp.broadcast_to(batch["images"][None],
                             (t,) + batch["images"].shape)
        for i, ((c, pool), p) in enumerate(zip(CIFARNET_SPEC,
                                               params["convs"])):
            x = _fold_t(lambda u: nn.conv2d(p["conv"], u), x)
            xf = x.reshape(-1, *x.shape[2:])
            yf, _ = nn.batchnorm(p["bn"], state["convs"][i], xf, train=False)
            x = _lif(yf.reshape(x.shape), cfg)
            out.append((f"conv{i}", float(1.0 - x.mean())))
            if pool == "mp":
                x = _fold_t(nn.maxpool2, x)
            elif pool == "ap":
                x = x.mean(axis=(2, 3))
        return out
    x, _ = _sps(params, state, cfg, batch["images"], train=False)
    out.append(("sps", float(1.0 - _lif(x, cfg).mean())))
    for i in range(cfg.num_layers):
        bp = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
        bst = jax.tree_util.tree_map(lambda a: a[i], state["blocks"])
        s_in = _lif(x, cfg)
        out.append((f"block{i}.in", float(1.0 - s_in.mean())))
        x, _ = _block(bp, bst, cfg, x, train=False)
    return out
