"""Spikingformer-8-512 — the paper's ImageNet workload (§V-A):
8 encoder blocks, embedding dim 512, T_s=4, 224x224 input (14x14 = 196
tokens after the 4-stage SPS)."""
from repro.core.engine import EngineConfig
from repro.core.spiking import SpikingConfig
from .base import ModelConfig, VisionSpec

CONFIG = ModelConfig(
    name="spikingformer-8-512", family="spikingformer",
    num_layers=8, d_model=512, num_heads=8, num_kv_heads=8, head_dim=64,
    d_ff=2048, vocab_size=1000,
    vision=VisionSpec(img_size=224, in_channels=3, sps_stages=4),
    spiking=SpikingConfig(time_steps=4),
    # auto on both engines: sparse matmuls + MXU-kernel SSA at the 196-
    # token ImageNet shape (see spikingformer_4_256 for the dispatch note)
    engine=EngineConfig(mode="auto", sparse="auto", overlap="auto"),
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, head_dim=16, d_ff=128,
    vocab_size=10,
    vision=VisionSpec(img_size=32, in_channels=3, sps_stages=4),
    spiking=SpikingConfig(time_steps=2), dtype="float32", remat=False)
