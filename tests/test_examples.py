"""Smoke-run every ``examples/`` script end to end.

Nothing else in the suite imports the examples, so they rot silently —
these tests execute each one in a subprocess (fresh interpreter, the
exact invocation the README advertises) in smoke mode and assert a clean
exit plus a recognizable line of output. Budget-heavy scripts are marked
``slow`` (PR CI skips them; pushes to main run the full tier).
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_example(script, *args, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", script), *args],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, \
        f"{script} failed:\n--- stdout ---\n{proc.stdout[-2000:]}\n" \
        f"--- stderr ---\n{proc.stderr[-2000:]}"
    return proc.stdout


def test_serve_lm_smoke():
    out = run_example("serve_lm.py", "--arch", "spikingformer-lm",
                      "--requests", "2", "--slots", "2",
                      "--prompt-len", "5", "--max-new", "2",
                      "--max-len", "32")
    assert "kv cache" in out and "requests" in out


def test_serve_lm_quantized_smoke():
    out = run_example("serve_lm.py", "--arch", "spikingformer-lm",
                      "--requests", "2", "--slots", "2",
                      "--prompt-len", "5", "--max-new", "2",
                      "--max-len", "32", "--quantize", "int8")
    assert "weights" in out and "int8" in out


@pytest.mark.slow
def test_quickstart_smoke():
    out = run_example("quickstart.py", "--steps", "3")
    assert "layer spike sparsity" in out


@pytest.mark.slow
def test_train_spikingformer_smoke():
    out = run_example("train_spikingformer.py", "--steps", "3",
                      "--batch", "4")
    assert "loss:" in out


@pytest.mark.slow
def test_dual_engine_walkthrough():
    out = run_example("dual_engine_walkthrough.py")
    assert "bitwise: True" in out
