"""Multi-pod dry-run: ``.lower().compile()`` every (arch x shape x mesh)
cell on the production meshes and record memory/cost/collective analysis.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-12b \
      --shape train_4k [--multi-pod]
  PYTHONPATH=src python -m repro.launch.dryrun --all

MUST set the placeholder device count before ANY other import — jax locks
the device count on first init.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512" + \
    (" " + os.environ.get("XLA_FLAGS_EXTRA", "") if
     os.environ.get("XLA_FLAGS_EXTRA") else "")

import argparse   # noqa: E402
import json       # noqa: E402
import re         # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax                                    # noqa: E402
import jax.numpy as jnp                       # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, SHAPES, get_config  # noqa: E402
from repro.models import registry             # noqa: E402
from repro.models.moe import use_ep_mesh      # noqa: E402
from repro.optim import adafactor, adamw      # noqa: E402
from repro.parallel import rules              # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch import steps                # noqa: E402

# archs where Adam's fp32 moments exceed HBM -> factored optimizer
ADAFACTOR_ARCHS = {"kimi-k2-1t-a32b"}
# weights-resident (ZeRO-1) fits everywhere except the 1T MoE (params
# alone are 2 TB bf16 -> must stay FSDP-sharded at 256 chips)
NO_ZERO1 = {"kimi-k2-1t-a32b"}


def resolve_scheme(arch: str, scheme: str) -> str:
    if scheme == "auto":
        return "fsdp" if arch in NO_ZERO1 else "zero1"
    return scheme

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {"pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
                "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2,
                "f32": 4, "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1,
                "f8e5m2": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string like 'bf16[8,128,4096]' or a tuple
    '(f32[...], f32[...])'."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str):
    """Sum result-shape bytes of every collective op, by op kind.

    HLO lines look like:  %ag = bf16[2,4096]{1,0} all-gather(...), ...
    For in-scan collectives the per-iteration bytes are what the line
    shows; we additionally multiply by the enclosing while trip count when
    it is statically printed — XLA names scan loops with
    "while(...)", trip counts are not in the text, so we instead count
    each textual occurrence once and report ops counts alongside
    (EXPERIMENTS.md documents the convention and scales by layer count).
    """
    out = {k: 0 for k in COLLECTIVE_OPS}
    counts = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],]+(?:\{[^}]*\})?)\s+([\w\-]+)", line)
        if not m:
            continue
        op = m.group(2)
        for kind in COLLECTIVE_OPS:
            if op == kind or op.startswith(kind + "-"):
                b = _shape_bytes(m.group(1))
                out[kind] += b
                counts[kind] += 1
                break
    return out, counts


def scan_trip_counts(hlo_text: str):
    """Best-effort extraction of while-loop trip counts (scan over layers)
    from the optimized HLO (XLA annotates known trip counts)."""
    trips = [int(x) for x in
             re.findall(r'known_trip_count=\{"?n"?[=:]"?(\d+)"?\}', hlo_text)]
    return trips


def build_cell(arch: str, shape_name: str, mesh, scheme: str = "fsdp"):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    params_abs = steps.abstract_params(cfg)
    pspecs = rules.params_partition(cfg, params_abs, mesh, scheme=scheme)
    pshard = rules.tree_shardings(pspecs, mesh)

    if shape.mode == "train":
        opt = (adafactor(1e-4) if arch in ADAFACTOR_ARCHS else
               adamw(1e-4))
        opt_abs = jax.eval_shape(opt.init, params_abs)
        # optimizer states stay FSDP-sharded in every scheme (ZeRO-1)
        ospecs = rules.params_partition(cfg, opt_abs, mesh, scheme="fsdp")
        oshard = rules.tree_shardings(ospecs, mesh)
        batch_abs = steps.batch_struct(cfg, shape)
        bspecs = rules.batch_partition(cfg, shape, mesh, batch_abs)
        bshard = rules.tree_shardings(bspecs, mesh)
        step_abs = jax.ShapeDtypeStruct((), jnp.int32)
        sshard = NamedSharding(mesh, P())
        fn = steps.build_train_step(cfg, opt)
        jitted = jax.jit(fn,
                         in_shardings=(pshard, oshard, sshard, bshard),
                         donate_argnums=(0, 1))
        args = (params_abs, opt_abs, step_abs, batch_abs)
    elif shape.mode == "prefill":
        batch_abs = steps.batch_struct(cfg, shape)
        bspecs = rules.batch_partition(cfg, shape, mesh, batch_abs)
        bshard = rules.tree_shardings(bspecs, mesh)
        fn = steps.build_prefill_step(cfg)
        dp = rules.batch_axes(shape, mesh)
        logits_spec = rules.fit_spec_to_shape(
            P(dp if len(dp) != 1 else dp[0], None, "model"),
            (shape.global_batch, shape.seq_len, cfg.vocab_size), mesh)
        jitted = jax.jit(fn, in_shardings=(pshard, bshard),
                         out_shardings=NamedSharding(mesh, logits_spec))
        args = (params_abs, batch_abs)
    else:  # decode
        cache_abs, tokens_abs, pos_abs = steps.decode_inputs_struct(cfg,
                                                                    shape)
        cspecs = rules.cache_partition(cfg, shape, mesh, cache_abs)
        cshard = rules.tree_shardings(cspecs, mesh)
        dp = rules.batch_axes(shape, mesh)
        tshard = NamedSharding(mesh, P(dp if len(dp) != 1 else dp[0], None))
        logits_spec = rules.fit_spec_to_shape(
            P(dp if len(dp) != 1 else dp[0], None, "model"),
            (shape.global_batch, 1, cfg.vocab_size), mesh)
        fn = steps.build_serve_step(cfg)
        jitted = jax.jit(
            fn, in_shardings=(pshard, cshard, tshard,
                              NamedSharding(mesh, P())),
            out_shardings=(NamedSharding(mesh, logits_spec), cshard),
            donate_argnums=(1,))
        args = (params_abs, cache_abs, tokens_abs, pos_abs)
    return cfg, jitted, args


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = "artifacts/dryrun", save_hlo: bool = False,
             scheme: str = "fsdp"):
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "scheme": scheme, "status": "ok"}
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and arch in registry.NO_LONG_CONTEXT:
        rec["status"] = "skipped_full_attention"
        _write(rec, out_dir)
        print(json.dumps(rec))
        return rec
    cfg = get_config(arch)
    if shape.is_decode and not registry.has_decode(cfg):
        rec["status"] = "skipped_no_decode"
        _write(rec, out_dir)
        return rec
    scheme = resolve_scheme(arch, scheme)
    rec["scheme"] = scheme
    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        t0 = time.time()
        from repro.parallel.sharding import rules_for_mesh, use_rules
        with use_ep_mesh(mesh, token_axes=("pod", "data"),
                         expert_axis="model"), \
                use_rules(rules_for_mesh(mesh)):
            cfg, jitted, args = build_cell(arch, shape_name, mesh,
                                           scheme=scheme)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll, coll_counts = collective_bytes(hlo)
        n_dev = mesh.devices.size
        rec.update({
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "devices": n_dev,
            "flops_total": cost.get("flops", -1.0),
            "bytes_accessed_total": cost.get("bytes accessed", -1.0),
            "collective_bytes_per_device": coll,
            "collective_op_counts": coll_counts,
            "scan_trip_counts": scan_trip_counts(hlo),
            "hlo_lines": hlo.count("\n"),
        })
        if mem is not None:
            for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes", "generated_code_size_in_bytes",
                         "peak_memory_in_bytes"):
                if hasattr(mem, attr):
                    rec[attr] = getattr(mem, attr)
            rec["memory_analysis_str"] = str(mem)[:2000]
        if save_hlo:
            hpath = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.hlo")
            os.makedirs(out_dir, exist_ok=True)
            with open(hpath, "w") as f:
                f.write(hlo)
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s "
              f"flops={rec['flops_total']:.3e} "
              f"coll={sum(coll.values()):.3e}B")
        print(rec.get("memory_analysis_str", "")[:400])
    except Exception as e:  # noqa: BLE001 — record failures, don't crash --all
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-3000:]
        print(f"[dryrun] FAIL {arch} x {shape_name} x {mesh_name}: "
              f"{rec['error']}")
    _write(rec, out_dir)
    return rec


def _write(rec, out_dir):
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir,
                        f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json")
    slim = {k: v for k, v in rec.items() if k != "traceback"}
    with open(path, "w") as f:
        json.dump(slim, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ALL_ARCHS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="all 10 assigned archs x 4 shapes")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--scheme", default="fsdp",
                    choices=["fsdp", "zero1", "auto"])
    args = ap.parse_args()

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if args.all:
        cells = [(a, s) for a in ASSIGNED_ARCHS for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]
    ok = True
    for arch, shape in cells:
        for mp in meshes:
            rec = run_cell(arch, shape, mp, out_dir=args.out,
                           save_hlo=args.save_hlo, scheme=args.scheme)
            ok &= rec["status"] != "error"
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
