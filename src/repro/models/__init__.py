from . import registry
from .registry import (FAMILIES, NO_DECODE, NO_LONG_CONTEXT, decode_step,
                       forward, has_decode, init, init_cache, init_state)
