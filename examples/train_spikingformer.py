"""End-to-end training driver (deliverable b): train a Spikingformer for a
few hundred steps with the full production substrate — checkpointing,
failure injection + supervised restart, straggler monitoring.

Default runs a CPU-sized model for speed; ``--full`` trains the paper's
Spikingformer-4-256 (~9.3M params — the paper's CIFAR workload);
``--d-model 1024 --layers 8`` reaches the ~100M class if you have the
cycles (same code path).

    PYTHONPATH=src python examples/train_spikingformer.py --steps 200
"""
import argparse
import sys
import tempfile

sys.path.insert(0, "src")

from repro.launch.train import train  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--full", action="store_true",
                    help="paper's Spikingformer-4-256 instead of smoke")
    ap.add_argument("--inject-failure-at", type=int, default=None)
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as ckpt:
        losses = train(
            "spikingformer-4-256", smoke=not args.full,
            total_steps=args.steps, batch=args.batch, seq=0, lr=2e-3,
            ckpt_dir=ckpt, ckpt_every=50,
            inject_failure_at=args.inject_failure_at, compress=False)
    print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{len(losses)} executed steps")


if __name__ == "__main__":
    main()
