"""Int8 gradient compression with error feedback (distributed-optimization
trick for the DP all-reduce at 1000+ node scale).

Each leaf is quantized to int8 with a per-leaf fp32 scale before the
data-parallel all-reduce; the quantization residual is kept locally and
added back the next step (error feedback keeps the method unbiased in the
long run — Karimireddy et al. 2019). Under GSPMD we express this as a
value transform around the gradient: XLA then all-reduces the int8 view.
8x less DP traffic at <0.1% loss delta on the synthetic tasks (tests).

The quantizer itself is ``repro.quant.quantize`` — one symmetric int8
core shared with the weight datapath (per-tensor scale here, per-output-
channel there; same round/clip semantics). Only the error-feedback loop
is gradient-specific.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.quant.quantize import (dequantize_values, quantize_values,
                                  symmetric_scale)


def int8_compress(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """-> (int8 values, fp32 scale). Symmetric per-tensor quantization
    (scale = max|x| / 127 with an epsilon floor, round-to-nearest)."""
    x32 = x.astype(jnp.float32)
    scale = symmetric_scale(x32, 8)
    return quantize_values(x32, scale, 8), scale


def int8_decompress(q: jax.Array, scale: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    return dequantize_values(q, scale, dtype)


def compress_state_init(params) -> Any:
    """Error-feedback residual buffers (same shapes as grads, fp32)."""
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_gradients(grads, error_state):
    """Apply int8 quantization + error feedback to a gradient tree.

    Returns (decompressed grads to feed the optimizer, new error state).
    The round-trip through int8 is what the DP all-reduce would carry.
    """
    def leaf(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = int8_compress(g32)
        deq = int8_decompress(q, scale)
        return deq.astype(g.dtype), g32 - deq

    flat = jax.tree_util.tree_map(leaf, grads, error_state)
    new_grads = jax.tree_util.tree_map(
        lambda x: x[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree_util.tree_map(
        lambda x: x[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    return new_grads, new_err
