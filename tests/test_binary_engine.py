"""Binary-engine dispatch (core/engine.py + core/attention.py): the MXU
spike-attention kernel pinned bit-exact against the bit-packed
AND-PopCount reference, whole-model parity across binary modes, and the
packed-KV serve path.

Bit-exactness strategy: on {0,1} spike operands every partial product is
0 or 1, so fp32 accumulation is *order-exact small-integer arithmetic* —
the MXU tiles, the VPU popcounts and the jnp einsum must all produce the
same integers, and the tests assert **int equality, not allclose** (the
AND-PopCount semantics the paper's LUT6 compressor trees compute). The
score threshold is the shared ``binarize`` expression ``(s - Δ) >= 0``,
so ties agree across engines too.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container has no hypothesis; use fixed-seed shim
    from _propcheck import given, settings, strategies as st

from repro.core import bitpack, engine as E
from repro.core.attention import spiking_attention
from repro.core.spiking import SpikingConfig
from repro.kernels import ops
from repro.kernels.popcount_attention import popcount_scores
from repro.kernels.spike_attention import spike_attention as attn_raw

SCFG = SpikingConfig(time_steps=2)


def _spikes(key, shape, density=0.25):
    return (jax.random.uniform(key, shape) < density).astype(jnp.float32)


def _popcount_reference(q, k, v, scale, delta, causal):
    """Integer-domain oracle built on bitpack.popcount_matmul: the LUT6
    compressor-tree semantics, end to end. Returns int32 context."""
    counts = bitpack.popcount_matmul(bitpack.pack_bits(q),
                                     bitpack.pack_bits(k))  # (BH, L, L)
    s = counts.astype(jnp.float32) * scale
    a = (s - delta >= 0).astype(jnp.int32)
    if causal:
        mask = jnp.tril(jnp.ones(counts.shape[-2:], bool))
        a = jnp.where(mask[None], a, 0)
    # context on int operands: attn {0,1} x spikes {0,1} -> exact counts
    return jnp.einsum("bqk,bkd->bqd", a, v.astype(jnp.int32))


# ---------------------------------------------------------------------------
# property suite: MXU kernel == AND-PopCount reference, as integers
# ---------------------------------------------------------------------------


@pytest.mark.slow
@settings(max_examples=24, deadline=None)
@given(st.sampled_from([16, 32, 48, 64, 100, 128]),   # L (incl. non-div)
       st.sampled_from([16, 32, 48, 64]),             # d_head (pack pads)
       st.sampled_from([32, 64, 128]),                # kernel block size
       st.floats(-0.5, 6.0),                          # threshold delta
       st.booleans())                                 # causal
def test_mxu_kernel_bit_exact_vs_popcount_reference(l, d, block, delta,
                                                    causal):
    ks = jax.random.split(jax.random.PRNGKey(l * 131 + d), 3)
    q, k, v = (_spikes(kk, (2, l, d)) for kk in ks)
    scale = 1.0 / np.sqrt(d)
    want = _popcount_reference(q, k, v, scale, delta, causal)
    got = attn_raw(q, k, v, scale=scale, delta=delta, causal=causal,
                   block_q=block, block_k=block)
    got_i = np.asarray(got).astype(np.int64)
    assert (np.asarray(got) == got_i).all()   # exact integers, no drift
    np.testing.assert_array_equal(got_i, np.asarray(want, np.int64))


@settings(max_examples=12, deadline=None)
@given(st.sampled_from([32, 64, 100]), st.sampled_from([32, 64]),
       st.floats(-0.5, 6.0), st.booleans())
def test_popcount_kernel_matches_mxu_kernel_bitwise(l, d, delta, causal):
    """The two Pallas ports of the binary engine agree to the bit on the
    full fused output (ops.binary_attention use_popcount=True/False)."""
    ks = jax.random.split(jax.random.PRNGKey(l + d * 7), 3)
    q, k, v = (_spikes(kk, (3, l, d)) for kk in ks)
    kw = dict(scale=1.0 / np.sqrt(d), delta=delta, causal=causal,
              block_q=64, block_k=64)
    mxu = ops.binary_attention(q, k, v, use_popcount=False, **kw)
    pop = ops.binary_attention(q, k, v, use_popcount=True, **kw)
    np.testing.assert_array_equal(np.asarray(mxu), np.asarray(pop))


def test_popcount_scores_pads_non_divisible_lengths():
    """lq=100 / lk=37 against 128-wide blocks: zero-padded, sliced back,
    still the exact overlap counts (the old code asserted divisibility)."""
    ks = jax.random.split(jax.random.PRNGKey(5), 2)
    q = _spikes(ks[0], (3, 100, 64))
    k = _spikes(ks[1], (3, 37, 64))
    got = popcount_scores(bitpack.pack_bits(q), bitpack.pack_bits(k),
                          block_q=128, block_k=128)
    exact = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.int32)
    assert got.shape == (3, 100, 37)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exact))


def test_pack_bits_pads_partial_words():
    """d=48 packs into 2 uint32 words with AND-PopCount-neutral zero
    bits; roundtrip and popcount_matmul stay exact."""
    x = _spikes(jax.random.PRNGKey(0), (5, 48), density=0.5)
    packed = bitpack.pack_bits(x)
    assert packed.shape == (5, 2)
    np.testing.assert_array_equal(
        np.asarray(bitpack.unpack_bits(packed, 48)), np.asarray(x))
    got = bitpack.popcount_matmul(packed, packed)
    want = (np.asarray(x) @ np.asarray(x).T).astype(np.int32)
    np.testing.assert_array_equal(np.asarray(got), want)


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------


def test_resolve_binary_mode_rules():
    auto = E.EngineConfig(binary="auto", min_flops=1 << 22)
    assert E.resolve_binary_mode(None, 64, 1024, 64) == "jnp"
    assert E.resolve_binary_mode(auto, 8, 16, 16) == "jnp"
    assert E.resolve_binary_mode(auto, 64, 256, 64) == "mxu_kernel"
    for mode in E.BINARY_MODES:  # explicit selection wins over volume
        eng = E.EngineConfig(binary=mode)
        assert E.resolve_binary_mode(eng, 1, 1, 1) == mode
    with pytest.raises(ValueError):
        E.resolve_binary_mode(E.EngineConfig(binary="cuda"), 1, 8, 8)


def test_spiking_attention_tri_mode_bit_parity():
    """One call site, three engines, identical bits — including a causal
    mask and a leading (T, B, H) dim stack that folds into BH."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q, k, v = (_spikes(kk, (2, 3, 4, 33, 16)) for kk in ks)
    outs = {}
    for mode in E.BINARY_MODES:
        eng = E.EngineConfig(binary=mode, attn_block_q=32, attn_block_k=32)
        with E.use_engine(eng):
            outs[mode] = np.asarray(spiking_attention(
                q, k, v, SCFG, delta_score=0.3, causal=True))
    np.testing.assert_array_equal(outs["jnp"], outs["mxu_kernel"])
    np.testing.assert_array_equal(outs["jnp"], outs["popcount"])


# ---------------------------------------------------------------------------
# whole-model parity (spikingformer SSA through the dispatch layer)
# ---------------------------------------------------------------------------


def _binary_engine(mode):
    # dense matmuls + small attention blocks: only the binary mode varies
    return E.EngineConfig(mode="dense", binary=mode,
                          attn_block_q=16, attn_block_k=16)


def _spikingformer_setup():
    from repro.configs import get_config
    from repro.models import registry

    cfg = get_config("spikingformer-4-256", smoke=True)
    params = registry.init(cfg, jax.random.PRNGKey(0))
    # dyadic-grid weights (multiples of 2^-8): every fp32 partial sum in
    # the *linear* layers is exact too, same trick as tests/test_engine.py
    params = jax.tree_util.tree_map(
        lambda a: jnp.round(a * 256) / 256 if a.dtype == jnp.float32 else a,
        params)
    batch = {"images": jax.random.normal(jax.random.PRNGKey(1),
                                         (2, 16, 16, 3)),
             "labels": jnp.zeros((2,), jnp.int32)}
    return cfg, params, batch, registry


@pytest.mark.parametrize("mode", ["mxu_kernel", "popcount"])
def test_spikingformer_logits_bit_identical_across_binary_modes(mode):
    """The whole SSA hot path — Q/K/V/O projections + binary attention —
    yields bitwise-equal logits whether attention runs in jnp, through
    the fused MXU kernel, or through the bit-packed popcount port."""
    cfg, params, batch, registry = _spikingformer_setup()
    with E.use_engine(_binary_engine("jnp")):
        ref_logits, _ = registry.forward(params, cfg, batch)
    with E.use_engine(_binary_engine(mode)):
        got, _ = registry.forward(params, cfg, batch)
    np.testing.assert_array_equal(np.asarray(ref_logits), np.asarray(got))


@pytest.mark.slow
def test_spikingformer_grads_match_across_binary_modes():
    """The kernel paths carry a surrogate-gradient custom VJP
    (kernels/ops.py recompute): d loss / d params agrees with the pure
    jnp surrogate path."""
    cfg, params, batch, registry = _spikingformer_setup()

    def loss(p, mode):
        with E.use_engine(_binary_engine(mode)):
            logits, _ = registry.forward(p, cfg, batch, train=True,
                                         state=registry.init_state(cfg))
        return (logits * logits).mean()

    g_jnp = jax.grad(loss)(params, "jnp")
    g_mxu = jax.grad(loss)(params, "mxu_kernel")
    flat_j, _ = jax.tree_util.tree_flatten(g_jnp)
    flat_m, _ = jax.tree_util.tree_flatten(g_mxu)
    total = 0.0
    for a, b in zip(flat_j, flat_m):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
        total += float(jnp.abs(a).sum())
    assert total > 0  # gradients actually flow through the SSA


# ---------------------------------------------------------------------------
# serve path: packed-KV decode == prefill (spiking LM)
# ---------------------------------------------------------------------------


def _decode_all(cfg, params, toks, registry, max_len=24):
    from repro.launch import steps as steps_lib

    cache = registry.init_cache(cfg, toks.shape[0], max_len)
    step = jax.jit(steps_lib.build_serve_step(cfg))
    outs = []
    for i in range(toks.shape[1]):
        lg, cache = step(params, cache, toks[:, i:i + 1],
                         jnp.asarray(i, jnp.int32))
        outs.append(lg)
    return jnp.concatenate(outs, axis=1), cache


def test_spiking_lm_packed_decode_matches_prefill():
    """spikingformer-lm under engine=auto: full-prompt prefill and
    token-by-token decode against the bit-packed spike KV cache agree on
    every logit, at a prompt length (13) that divides neither the
    attention blocks nor the 32-bit pack words (head_dim=16)."""
    from repro.configs import get_config
    from repro.launch import steps as steps_lib
    from repro.models import registry

    cfg = get_config("spikingformer-lm", smoke=True)
    assert cfg.engine.packed_kv and cfg.engine.binary == "auto"
    params = registry.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 13)), jnp.int32)
    prefill = jax.jit(steps_lib.build_prefill_step(cfg))
    logits = prefill(params, {"tokens": toks})
    dec, cache = _decode_all(cfg, params, toks, registry)
    # the cache really is the compressed layout: uint32 words, one word
    # for the 16 spike channels (padded), not 16 floats
    assert cache["layers"]["k"].dtype == jnp.uint32
    assert cache["layers"]["k"].shape[-1] == 1
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits),
                               atol=2e-4, rtol=2e-4)


def test_spiking_lm_packed_and_unpacked_decode_bit_identical():
    """packed_kv is pure compression: AND-PopCount scores against uint32
    words reproduce the fp32 spike dots bit-for-bit."""
    from repro.configs import get_config
    from repro.models import registry

    cfg = get_config("spikingformer-lm", smoke=True)
    params = registry.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 9)), jnp.int32)
    dec_packed, _ = _decode_all(cfg, params, toks, registry)
    cfg_unpacked = cfg.replace(engine=cfg.engine.replace(packed_kv=False))
    dec_plain, cache = _decode_all(cfg_unpacked, params, toks, registry)
    assert cache["layers"]["k"].dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(dec_packed),
                                  np.asarray(dec_plain))
