"""Dual-engine dispatch: per-matmul *and* per-attention engine selection.

FireFly-T's overlay couples a *sparse engine* (spike x weight projections,
zero-skipping) with a *binary engine* (QK^T / QK^T V, AND-PopCount). This
module is the orchestrator (DESIGN.md §3/§4) for both halves:

Sparse engine — every spiking matmul (Q/K/V/O projections, the MLP,
anything whose input is a {0,1} spike tensor) routes through
:func:`spike_linear`, which picks per call site between

  * ``dense``  — plain XLA dot, fp32 accumulation (the measurement
    baseline every perf PR compares against), and
  * ``sparse`` — one of two zero-skipping Pallas datapaths, selected by
    ``EngineConfig.sparse`` (tile | decoded | auto, DESIGN.md §9):
    the block-sparse ``spike_matmul`` kernel skips all-zero (block_m x
    block_k) spike tiles via the occupancy map, and the gather-compacted
    ``spike_decode`` kernel prefix-compacts each row's non-zero
    K-indices and contracts only the live weight rows, with rows binned
    into pow2 occupancy buckets for uniform per-step work (the
    fine-grained/ragged-sparsity regime the tile skip can't touch).

Binary engine — every spiking self-attention (``core.attention.
spiking_attention``, the transformer family's spiking SSA) consults
:func:`resolve_binary_mode` for its execution target:

  * ``jnp``        — the pure-jnp reference dataflow (scores, binarize,
    context), the baseline the kernels are pinned against;
  * ``mxu_kernel`` — the fused single-pass Pallas kernel
    (``kernels/spike_attention``): {0,1} dot products on the MXU *are*
    AND-PopCount, the L x L attention matrix never leaves VMEM;
  * ``popcount``   — the literal FPGA port (``kernels/
    popcount_attention``): spikes bit-packed 32x into uint32 lanes,
    scores via VPU ``population_count``. Kept first-class to pin the
    AND-PopCount semantics and to quantify that the MXU form dominates
    on TPU (never chosen by ``auto``).

Fused overlap — ``EngineConfig.overlap = off|fused|pipeline|auto`` lets
an engine-owned step run as *one* Pallas grid in which the two engines
execute interleaved per head — the paper's Fig. 5 latency-hiding
schedule made structural instead of sequential-composition-plus-
arithmetic-model. Two step surfaces exist: the SSA bundle
(:func:`ssa_step` / :func:`ssa_step_causal` — Q/K/V projections +
epilogues + binary attention, ``kernels/fused_ssa.py``) and the *layer
program* (:func:`layer_step` / :func:`layer_step_causal` — the bundle
plus output projection, residuals and the spiking MLP as one grid,
``kernels/fused_layer.py``). The layer program's ``pipeline`` mode
additionally walks the timestep axis as a grid dimension (the
timestep/layer wavefront from ROADMAP), and :func:`resolve_layer_plan`
folds the overlap mode and the sparse datapath into one static plan so
``sparse='decoded'`` rides inside ``overlap='fused'|'pipeline'``.

Dispatch is *static* (shape/config driven, resolved at trace time): jit
can't branch on runtime density, so ``auto`` mode uses the flop volume as
the proxy — tiny matmuls / tiny attention can't amortize kernel staging
and stay on the XLA path. The engine is installed ambiently
(thread-local, like sharding rules) by the step builders from
``ModelConfig.engine``, so model code stays free of engine plumbing.
Off-TPU the kernels run in ``interpret`` mode — the bit-exact Python
evaluation this container's tests validate against.

Both engines carry custom VJPs (dense fp32 transposes / surrogate-
gradient recompute in bwd): spike inputs come from surrogate-gradient
LIF neurons, so training steps differentiate straight through dispatch.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import math
import threading
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


SPARSE_PATHS = ("tile", "decoded")
OVERLAP_MODES = ("off", "fused", "pipeline")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Dual-engine dispatch knobs (per model, set on ModelConfig.engine).

    Sparse engine (spike x weight matmuls):
    mode: 'dense' | 'sparse' | 'auto'. 'auto' goes sparse only when the
      matmul's flop volume clears ``min_flops`` (occupancy staging and
      per-block control flow need real work to amortize — and it keeps
      CPU smoke configs on the fast XLA path).
    sparse: 'tile' | 'decoded' | 'auto' — which sparse datapath a
      sparse-resolved matmul runs (DESIGN.md §9):
      - 'tile': the block-occupancy kernel (skips whole block_m x
        block_k spike tiles) — the conservative default, profitable at
        *coherent* sparsity;
      - 'decoded': the gather-compacted kernel
        (kernels/spike_decode.py) — per-row non-zero K-indices are
        prefix-compacted and only the live weight rows are contracted,
        with rows binned into pow2 occupancy buckets so every grid step
        does uniform work. Wins at fine-grained / ragged sparsity where
        whole tiles almost never go dark;
      - 'auto': picks per call from the *concrete* occupancy histogram
        (kernels/spike_decode.choose_sparse_path — tile skip fraction
        vs bucket-schedule MAC fraction with the decoded path's
        overhead handicap). Under jit the spikes are traced and the
        histogram is unobservable, so auto falls back to 'tile' — the
        same static-dispatch principle as ``mode`` / ``binary``.
    block_*: VMEM tile sizes of the kernel; (block_m x block_k) is the
      tile path's skip granularity and block_k doubles as the decoded
      path's compacted-chunk width.

    Binary engine (spiking self-attention):
    binary: 'jnp' | 'mxu_kernel' | 'popcount' | 'auto'. 'auto' picks the
      fused MXU kernel when the attention flop volume (both matmuls,
      4 * BH * L^2 * d) clears ``min_flops``, else the jnp reference;
      'popcount' (the bit-packed VPU port) is only ever explicit — the
      benchmarks document that the MXU form dominates on TPU.
    attn_block_q / attn_block_k: KV-tile sizes of the attention kernels
      (non-divisible L is zero-padded inside the kernels).
    packed_kv: spiking decode caches store K/V bit-packed (uint32, the
      paper's 32x spike-RAM compression) and score against them with
      AND-PopCount; layout is static per config, so this lives here and
      not in the ambient state.

    overlap: 'off' | 'fused' | 'pipeline' | 'auto' — whether an
      engine-owned step runs as a fused dual-engine grid (projection
      tiles and AND-PopCount tiles interleaved per head, the Fig. 5
      overlap made structural) or as the sequential composition.
      'fused' runs the whole-layer program (kernels/fused_layer.py) for
      layer_step/layer_step_causal and the SSA bundle
      (kernels/fused_ssa.py) for ssa_step/ssa_step_causal; 'pipeline'
      is the layer program on its (B, T, P, H) wavefront grid — the
      timestep axis becomes a grid dimension so MLP tiles of layer l
      interleave with layer l+1's Q/K/V phases on a pipelined backend
      (bundle-level steps treat it as 'fused': the bundle has no MLP
      tail to pipeline). 'auto' fuses only when the step's flop volume
      clears ``min_flops``, the input is concrete, and the backend is
      interpretable (same static-dispatch discipline as ``sparse``:
      under jit / on a real TPU auto resolves 'off'; explicit
      'fused'/'pipeline' are honored everywhere — auto never volunteers
      'pipeline'). The fused steps are eval-only (train-mode BN needs
      global batch stats) and fall back to the sequential composition
      for layer shapes they do not cover (bias terms, mixed
      quantization, GQA, qk_norm, gated MLPs — see layer_step /
      layer_step_causal / ssa_step / ssa_step_causal).

    weights: weight datapath dtype — 'fp32' (native params), 'int8', or
      'int4'. This is the *declared* serving datapath (launch/serve.py
      --quantize sets it and quantizes the params at load; repro.quant);
      per-call dispatch is transparent on the param dict — a quantized
      ``{"qw","scale"[,"b"]}`` dict routes through the int8-accumulating
      kernel (sparse) or the int-exact fp32 reference (dense) whatever
      this field says, so mixed trees (fp embeddings + int8 linears) just
      work.

    interpret: force Pallas interpret mode (None = auto: off-TPU only).
    """
    mode: str = "auto"
    sparse: str = "tile"
    block_m: int = 128
    block_n: int = 128
    block_k: int = 128
    min_flops: int = 1 << 22
    binary: str = "auto"
    attn_block_q: int = 128
    attn_block_k: int = 128
    packed_kv: bool = True
    overlap: str = "off"
    weights: str = "fp32"
    interpret: Optional[bool] = None

    def __post_init__(self):
        if self.weights not in ("fp32", "int8", "int4"):
            raise ValueError(f"unknown weights datapath {self.weights!r} "
                             f"(expected fp32|int8|int4)")
        if self.sparse not in SPARSE_PATHS + ("auto",):
            raise ValueError(f"unknown sparse datapath {self.sparse!r} "
                             f"(expected tile|decoded|auto)")
        if self.overlap not in OVERLAP_MODES + ("auto",):
            raise ValueError(f"unknown overlap mode {self.overlap!r} "
                             f"(expected off|fused|pipeline|auto)")

    def replace(self, **kw) -> "EngineConfig":
        return dataclasses.replace(self, **kw)


DENSE = EngineConfig(mode="dense")
SPARSE = EngineConfig(mode="sparse")

_state = threading.local()


def set_engine(engine: Optional[EngineConfig]) -> None:
    _state.engine = engine


def get_engine() -> Optional[EngineConfig]:
    return getattr(_state, "engine", None)


class use_engine:
    """Context manager installing the ambient engine (mirrors
    sharding.use_rules). ``use_engine(None)`` disables dispatch."""

    def __init__(self, engine: Optional[EngineConfig]):
        self.engine = engine

    def __enter__(self):
        self.prev = get_engine()
        set_engine(self.engine)
        return self.engine

    def __exit__(self, *exc):
        set_engine(self.prev)


def engine_scope(cfg) -> contextlib.AbstractContextManager:
    """Engine context for a model config: installs ``cfg.engine`` when the
    config sets one, otherwise leaves the ambient engine untouched (so a
    caller-installed engine survives step builders for engine-less
    configs)."""
    engine = getattr(cfg, "engine", None)
    if engine is None:
        return contextlib.nullcontext()
    return use_engine(engine)


def annotate(name: str) -> contextlib.AbstractContextManager:
    """Profiler scope for an engine dispatch site (``jax.named_scope``):
    every sparse-engine matmul, binary-engine attention, and fused
    dual-engine step carries one, so the overlap is legible in a profile
    dump (xprof / jax.profiler). Purely metadata — annotated and
    unannotated traces are bitwise-identical (pinned by tests) — and
    toggleable via :func:`disable_annotations` to prove exactly that.
    """
    if getattr(_state, "no_annotations", False):
        return contextlib.nullcontext()
    return jax.named_scope(name)


@contextlib.contextmanager
def disable_annotations():
    """Run without profiler scopes (the bitwise smoke test's control arm)."""
    prev = getattr(_state, "no_annotations", False)
    _state.no_annotations = True
    try:
        yield
    finally:
        _state.no_annotations = prev


def resolve_mode(engine: Optional[EngineConfig], m: int, k: int, n: int
                 ) -> str:
    """Static dense/sparse decision for an (M, K) x (K, N) spike matmul."""
    if engine is None:
        return "dense"
    if engine.mode in ("dense", "sparse"):
        return engine.mode
    if engine.mode != "auto":
        raise ValueError(f"unknown engine mode {engine.mode!r}")
    return "sparse" if 2 * m * k * n >= engine.min_flops else "dense"


def resolve_sparse_path(engine: Optional[EngineConfig],
                        s2d: Optional[jax.Array] = None) -> str:
    """Tile-vs-decoded decision for a sparse-resolved matmul.

    Static when it has to be: 'auto' consults the concrete occupancy
    histogram (the decoded path's per-call crossover, DESIGN.md §9) only
    when the spikes are concrete — under jit the input is a tracer and
    auto resolves 'tile', the conservative static default. On a real TPU
    backend auto also resolves 'tile': the decoded kernel's in-kernel
    row gather is validated in interpret mode but not yet against Mosaic
    lowering (DESIGN.md §9 caveat), so auto never volunteers it there —
    an explicit 'tile'/'decoded' declaration is honored everywhere.
    """
    if engine is None:
        return "tile"
    if engine.sparse in SPARSE_PATHS:
        return engine.sparse
    # EngineConfig.__post_init__ already rejected anything else
    assert engine.sparse == "auto", engine.sparse
    if s2d is None or isinstance(s2d, jax.core.Tracer):
        return "tile"
    if jax.default_backend() == "tpu":
        return "tile"
    from repro.kernels.spike_decode import choose_sparse_path  # lazy
    return choose_sparse_path(s2d, engine.block_m, engine.block_k)


BINARY_MODES = ("jnp", "mxu_kernel", "popcount")


def resolve_binary_mode(engine: Optional[EngineConfig], bh: int, l: int,
                        d: int) -> str:
    """Static binary-engine decision for a (BH, L, d) spiking attention.

    ``bh`` is the folded batch x heads dim; the workload is two L x L x d
    matmuls per batch entry (QK^T and attn @ V — no softmax between, see
    kernels/spike_attention). 'auto' never picks 'popcount': the MXU
    kernel dominates it on TPU (DESIGN.md §3); the popcount path is an
    explicit, semantics-pinning selection.
    """
    if engine is None:
        return "jnp"
    if engine.binary in BINARY_MODES:
        return engine.binary
    if engine.binary != "auto":
        raise ValueError(f"unknown binary engine mode {engine.binary!r}")
    return "mxu_kernel" if 4 * bh * l * l * d >= engine.min_flops else "jnp"


def resolve_overlap(engine: Optional[EngineConfig],
                    x: Optional[jax.Array] = None,
                    flops: int = 0) -> str:
    """Fused-vs-sequential decision for an SSA layer step.

    Same static-dispatch discipline as :func:`resolve_sparse_path`:
    'auto' fuses only when the input is concrete (under jit — e.g. inside
    the block scan — it is a tracer and auto resolves 'off'), off a real
    TPU backend (the fused kernel is validated in interpret mode, not yet
    against Mosaic lowering), and when the bundle's flop volume
    (three projections + both attention matmuls) clears ``min_flops`` —
    the fused grid stages whole Q/K/V spike trains through VMEM scratch,
    which tiny smoke shapes can't amortize. Explicit 'fused' and
    'pipeline' are honored everywhere; 'auto' never volunteers
    'pipeline' (the wavefront grid's payoff is a backend-scheduling
    property, not something the flop proxy can see).
    """
    if engine is None:
        return "off"
    if engine.overlap in OVERLAP_MODES:
        return engine.overlap
    # EngineConfig.__post_init__ already rejected anything else
    assert engine.overlap == "auto", engine.overlap
    if x is None or isinstance(x, jax.core.Tracer):
        return "off"
    if jax.default_backend() == "tpu":
        return "off"
    return "fused" if flops >= engine.min_flops else "off"


class LayerPlan(NamedTuple):
    """The static execution plan of a whole-layer step: which overlap
    grid (off | fused | pipeline) and which sparse projection datapath
    (tile | decoded) the fused layer program composes."""
    overlap: str
    sparse: str


def resolve_layer_plan(engine: Optional[EngineConfig],
                       x: Optional[jax.Array] = None,
                       flops: int = 0) -> LayerPlan:
    """One static plan for a whole-layer step.

    PR 6 resolved the overlap mode (:func:`resolve_overlap`, per bundle)
    and the sparse datapath (:func:`resolve_sparse_path`, per matmul)
    independently — the layer program needs them as *one* decision so
    ``sparse='decoded'`` rides inside ``overlap='fused' | 'pipeline'``
    (the decoded gather runs *inside* the fused kernel's projection
    phases). Same static-dispatch discipline as both parents: under jit
    ``x`` is a tracer, so 'auto' resolves (off, tile).
    """
    overlap = resolve_overlap(engine, x, flops)
    x2d = None
    if x is not None and not isinstance(x, jax.core.Tracer):
        x2d = x.reshape(-1, x.shape[-1])
    return LayerPlan(overlap, resolve_sparse_path(engine, x2d))


# ---------------------------------------------------------------------------
# sparse path: Pallas kernel fwd (tile or decoded), dense-transpose bwd
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _sparse_matmul(s2d, w, b, block_m, block_n, block_k, path, interpret):
    # keep the fp32 accumulator: spike_linear casts once to the
    # activation dtype, exactly like the dense reference — a w.dtype
    # round-trip here would break bit-parity for mixed dtypes.
    if path == "decoded":
        from repro.kernels.spike_decode import gather_spike_matmul  # lazy
        return gather_spike_matmul(s2d, w, bias=b, block_m=block_m,
                                   block_n=block_n, c_block=block_k,
                                   interpret=interpret)
    from repro.kernels.spike_matmul import spike_matmul  # lazy: no cycle
    return spike_matmul(s2d, w, bias=b, block_m=block_m, block_n=block_n,
                        block_k=block_k, out_dtype=jnp.float32,
                        interpret=interpret)


def _sparse_fwd(s2d, w, b, block_m, block_n, block_k, path, interpret):
    out = _sparse_matmul(s2d, w, b, block_m, block_n, block_k, path,
                         interpret)
    return out, (s2d, w, b)


def _sparse_bwd(block_m, block_n, block_k, path, interpret, res, g):
    s2d, w, b = res
    g32 = g.astype(jnp.float32)
    ds = jnp.dot(g32, w.astype(jnp.float32).T,
                 preferred_element_type=jnp.float32).astype(s2d.dtype)
    dw = jnp.dot(s2d.astype(jnp.float32).T, g32,
                 preferred_element_type=jnp.float32).astype(w.dtype)
    db = None if b is None else g32.sum(axis=0).astype(b.dtype)
    return ds, dw, db


_sparse_matmul.defvjp(_sparse_fwd, _sparse_bwd)


# ---------------------------------------------------------------------------
# quantized sparse path: int8-accumulating Pallas kernel fwd, dequantized
# dense transposes bwd (repro.quant weight datapath, DESIGN.md §8)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8, 9))
def _quant_sparse_matmul(s2d, qw, scale, b, block_m, block_n, block_k,
                         path, counts, interpret):
    if path == "decoded":
        from repro.kernels.spike_decode import \
            quant_gather_spike_matmul  # lazy
        return quant_gather_spike_matmul(
            s2d, qw, scale, bias=b, block_m=block_m, block_n=block_n,
            c_block=block_k, counts=counts, interpret=interpret)
    from repro.kernels.spike_matmul import quant_spike_matmul  # lazy
    return quant_spike_matmul(s2d, qw, scale, bias=b, block_m=block_m,
                              block_n=block_n, block_k=block_k,
                              counts=counts, interpret=interpret)


def _quant_sparse_fwd(s2d, qw, scale, b, block_m, block_n, block_k,
                      path, counts, interpret):
    out = _quant_sparse_matmul(s2d, qw, scale, b, block_m, block_n,
                               block_k, path, counts, interpret)
    return out, (s2d, qw, scale, b)


def _quant_sparse_bwd(block_m, block_n, block_k, path, counts, interpret,
                      res, g):
    """ds flows through the *dequantized* weights (the fp32 function the
    int kernel computes); int8 codes get a float0 cotangent (integer
    leaves are non-differentiable); scale/bias get their true grads so a
    forward under jax.grad never silently zeroes a float leaf."""
    import numpy as np
    s2d, qw, scale, b = res
    g32 = g.astype(jnp.float32)
    w_deq = qw.astype(jnp.float32) * scale[None, :]
    ds = jnp.dot(g32, w_deq.T,
                 preferred_element_type=jnp.float32).astype(s2d.dtype)
    acc = jnp.dot(s2d.astype(jnp.float32), qw.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    dscale = (g32 * acc).sum(axis=0).astype(scale.dtype)
    dqw = np.zeros(qw.shape, dtype=jax.dtypes.float0)
    db = None if b is None else g32.sum(axis=0).astype(b.dtype)
    return ds, dqw, dscale, db


_quant_sparse_matmul.defvjp(_quant_sparse_fwd, _quant_sparse_bwd)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


def dense_spike_linear(p: Dict[str, Any], x: jax.Array) -> jax.Array:
    """The dense reference: fp32-accumulated dot + bias, cast back to the
    activation dtype — term-for-term what the sparse kernel computes.

    Operands stay in their native dtype (no hoisted upcasts — bf16 feeds
    the MXU directly and the result is cast back before any collective,
    preserving the §Perf F1 bf16 traffic); only the accumulator is fp32.
    """
    y = jnp.dot(x, p["w"], preferred_element_type=jnp.float32)
    if "b" in p:
        y = y + p["b"].astype(jnp.float32)
    return y.astype(x.dtype)


def _unpacked_qw(p: Dict[str, Any], k: int) -> jax.Array:
    """int8 weight codes from a quantized param dict (int4 nibbles are
    unpacked to int8 at dispatch; storage stays packed)."""
    qw = p["qw"]
    if qw.dtype == jnp.uint8:
        from repro.quant.quantize import unpack_int4  # lazy: no cycle
        qw = unpack_int4(qw, k)
    return qw


def dense_quant_linear(p: Dict[str, Any], x: jax.Array) -> jax.Array:
    """The quantized dense reference: fp32-accumulated dot against the raw
    int codes, per-output-channel scale + bias in the epilogue, cast back
    to the activation dtype.

    On {0,1} spike inputs every partial sum is a small integer held
    exactly in fp32, so this equals the int32-accumulating kernel
    bitwise; on analog inputs it is weight-only quantized compute (the
    int codes dequantize on the fly through the epilogue scale).
    """
    k = x.shape[-1]
    qw = _unpacked_qw(p, k)
    acc = jnp.dot(x, qw.astype(x.dtype),
                  preferred_element_type=jnp.float32)
    y = acc * p["scale"].astype(jnp.float32)
    if "b" in p:
        y = y + p["b"].astype(jnp.float32)
    return y.astype(x.dtype)


def spike_linear(p: Dict[str, Any], x: jax.Array, *,
                 engine: Optional[EngineConfig] = None,
                 counts: bool = False) -> jax.Array:
    """Dual-engine linear layer for spike (or spike-derived sparse) inputs.

    p: {'w': (K, N)[, 'b': (N,)]} param dict (models/nn.py layout), or the
    quantized layout {'qw', 'scale'[, 'b']} (repro.quant) — quantized
    dicts route through the int8-accumulating kernel on the sparse path
    and the int-exact fp32 reference on the dense path;
    x: (..., K) activations — {0,1} spikes or the sparse integer counts a
    binary-attention context carries; the count call sites declare
    ``counts=True`` so the quantized kernel gives the left operand int32
    lanes (an int8 cast would wrap counts >= 128 — spikes stay int8, the
    MXU fast path). Leading dims fold into the sparse engine's M.
    ``engine=None`` uses the ambient engine (see use_engine); no ambient
    engine means dense.
    """
    engine = engine if engine is not None else get_engine()
    k = x.shape[-1]
    quantized = "qw" in p
    if engine is not None and engine.weights != "fp32":
        # the declared datapath is a contract, not a comment: a config
        # serving int8 must actually be handed int8 codes (catches a
        # quantize-at-load step that missed a linear, or width mismatch).
        # An int4 declaration accepts int8-dtyped codes too: the int4
        # quantizer deliberately leaves odd-K linears as int8-stored
        # 4-bit codes (quantize_weight), indistinguishable by dtype.
        ok = quantized and (engine.weights == "int4"
                            or p["qw"].dtype == jnp.int8)
        if not ok:
            actual = "fp32 (unquantized)" if not quantized \
                else "packed int4"
            raise ValueError(
                f"engine declares weights={engine.weights!r} but this "
                f"linear's params are {actual} (quantize_tree the params "
                f"or fix EngineConfig.weights)")
    n = (p["qw"] if quantized else p["w"]).shape[-1]
    m = 1
    for d in x.shape[:-1]:
        m *= d
    if resolve_mode(engine, m, k, n) == "dense":
        with annotate("sparse_engine.dense"):
            return dense_quant_linear(p, x) if quantized \
                else dense_spike_linear(p, x)
    x2d = x.reshape(-1, k)
    path = resolve_sparse_path(engine, x2d)
    with annotate(f"sparse_engine.{path}"):
        if quantized:
            out = _quant_sparse_matmul(
                x2d.astype(jnp.float32), _unpacked_qw(p, k),
                p["scale"].astype(jnp.float32), p.get("b"),
                engine.block_m, engine.block_n, engine.block_k,
                path, counts, engine.interpret)
        else:
            out = _sparse_matmul(x2d, p["w"], p.get("b"),
                                 engine.block_m, engine.block_n,
                                 engine.block_k, path, engine.interpret)
    return out.reshape(*x.shape[:-1], n).astype(x.dtype)


# ---------------------------------------------------------------------------
# fused dual-engine SSA step (overlap='fused'): one Pallas grid runs the
# sparse engine (Q/K/V projections + epilogues) and the binary engine
# (AND-PopCount attention) interleaved per head — kernels/fused_ssa.py,
# the Fig. 5 schedule. Custom VJP recomputes the sequential oracle in bwd.
# ---------------------------------------------------------------------------


class _BundleSpec(NamedTuple):
    """Static (hashable) closure of a fused SSA step — the nondiff arg of
    the custom VJP, shared verbatim by the kernel fwd and the oracle bwd."""
    family: str
    num_heads: int
    head_dim: int
    scale: float
    causal: bool
    scfg: Any                   # SpikingConfig (frozen dataclass)
    eps: float
    interpret: Optional[bool]


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _fused_bundle(x, w3, scale3, aux, delta, spec):
    from repro.kernels.fused_ssa import fused_ssa  # lazy: no cycle
    out, _ = fused_ssa(
        x, w3, scale3, aux, delta, family=spec.family,
        num_heads=spec.num_heads, head_dim=spec.head_dim, scale=spec.scale,
        causal=spec.causal, binarize_scores=spec.scfg.binarize_scores,
        decay=spec.scfg.decay, v_th=spec.scfg.v_threshold,
        soft_reset=spec.scfg.soft_reset, eps=spec.eps,
        interpret=spec.interpret)
    return out


def _fused_fwd(x, w3, scale3, aux, delta, spec):
    return _fused_bundle(x, w3, scale3, aux, delta, spec), \
        (x, w3, scale3, aux, delta)


def _fused_bwd(spec, res, g):
    """Recompute-through-the-oracle bwd: differentiating
    ``kernels.fused_ssa.reference_bundle`` (the sequential composition the
    kernel is pinned against bitwise) gives exactly the sequential path's
    gradients — surrogate LIF/binarize jvps included. Quantized int codes
    are cast to the activation dtype *before* this boundary, so their
    cotangent stops at the convert just like the dense path's."""
    from repro.kernels.fused_ssa import reference_bundle  # lazy: no cycle
    x, w3, scale3, aux, delta = res

    def f(x_, w3_, scale3_, aux_, delta_):
        return reference_bundle(
            x_, w3_, scale3_, aux_, delta_, spec.scfg, family=spec.family,
            num_heads=spec.num_heads, head_dim=spec.head_dim,
            scale=spec.scale, causal=spec.causal, eps=spec.eps)

    _, vjp = jax.vjp(f, x, w3, scale3, aux, delta)
    return vjp(g)


_fused_bundle.defvjp(_fused_fwd, _fused_bwd)


def ssa_step(p: Dict[str, Any], st: Dict[str, Any], cfg, s: jax.Array, *,
             train: bool = False,
             engine: Optional[EngineConfig] = None):
    """The vision-family SSA bundle (bidirectional, BN epilogues):
    projections Q/K/V (+ BatchNorm + LIF) and binary attention as one
    engine-owned step. ``models/spikingformer._ssa`` hands the whole
    bundle here instead of composing primitives itself.

    p: {'wq','wk','wv','bn_q','bn_k','bn_v','delta', ...}; st: the BN
    running-stats subtree; s: (T, B, L, D) {0,1} spikes (post input LIF);
    cfg: ModelConfig. Returns (ctx (T, B, L, q_dim), new BN state).

    ``overlap='fused'`` runs the pipelined dual-engine kernel when the
    step is expressible there: eval only (train-mode BN needs global
    batch statistics), bias-free projections, all-or-none quantization.
    Otherwise — and always for ``overlap='off'`` — the sequential
    composition below, which is the bit-parity reference.
    """
    engine = engine if engine is not None else get_engine()
    from repro.core.attention import spiking_attention  # lazy: no cycle
    from repro.core.spiking import lif_scan
    from repro.models import nn
    t, b, l, d = s.shape
    heads, hd = cfg.num_heads, cfg.head_dim
    names = (("q", "wq"), ("k", "wk"), ("v", "wv"))
    quant = ["qw" in p[w] for _, w in names]
    flops = 6 * (t * b * l) * d * cfg.q_dim \
        + 4 * (t * b * heads) * l * l * hd
    eligible = (not train
                and (all(quant) or not any(quant))
                and not any("b" in p[w] for _, w in names))
    if eligible and resolve_overlap(engine, s, flops) in ("fused",
                                                          "pipeline"):
        if all(quant):
            w3 = jnp.stack([_unpacked_qw(p[w], d) for _, w in names]
                           ).astype(s.dtype)
            scale3 = jnp.stack([p[w]["scale"].astype(jnp.float32)
                                for _, w in names])
        else:
            w3 = jnp.stack([p[w]["w"] for _, w in names])
            scale3 = None
        aux = jnp.stack([
            jnp.stack([st[f"bn_{n}"]["mean"].astype(jnp.float32),
                       st[f"bn_{n}"]["var"].astype(jnp.float32),
                       p[f"bn_{n}"]["scale"].astype(jnp.float32),
                       p[f"bn_{n}"]["bias"].astype(jnp.float32)])
            for n, _ in names])
        spec = _BundleSpec("bn", heads, hd, 1.0 / math.sqrt(hd), False,
                           cfg.spiking, 1e-5, engine.interpret)
        with annotate("dual_engine.fused_ssa"):
            ctx = _fused_bundle(s, w3, scale3, aux, p["delta"], spec)
        return ctx, dict(st)
    # sequential composition (what models/spikingformer._ssa used to
    # inline) — the reference the fused path is pinned against bitwise
    new_st = dict(st)

    def proj(name, w):
        cur = nn.linear(p[w], s, spikes=True)
        y, bn_st = nn.batchnorm(p[f"bn_{name}"], st[f"bn_{name}"],
                                cur.reshape(-1, cur.shape[-1]), train=train)
        new_st[f"bn_{name}"] = bn_st
        sp, _ = lif_scan(y.reshape(cur.shape), cfg.spiking)
        return sp

    q_s = proj("q", "wq")
    k_s = proj("k", "wk")
    v_s = proj("v", "wv")
    # (T,B,L,q_dim) -> (T*B, H, L, hd) for the binary-attention primitive
    fold = lambda u: u.reshape(t * b, l, heads, hd).transpose(0, 2, 1, 3)
    ctx = spiking_attention(fold(q_s), fold(k_s), fold(v_s), cfg.spiking,
                            delta_score=p["delta"])
    return ctx.transpose(0, 2, 1, 3).reshape(t, b, l, cfg.q_dim), new_st


def ssa_step_causal(p: Dict[str, Any], cfg, h: jax.Array, positions, *,
                    train: bool = False,
                    engine: Optional[EngineConfig] = None) -> jax.Array:
    """The token-family SSA bundle (causal, RoPE epilogues): Q/K/V
    projections (+ RoPE + LIF) and causal binary attention as one
    engine-owned step — the spiking full-attention branch of
    ``models/transformer.apply_layer`` hands the bundle here (the
    sliding-window branch keeps its banded jnp dataflow).

    h: (T, B, S, D) normed membrane currents (post ln1); positions: (S,).
    Returns attn (T, B, S, q_dim) — pre-wo context.

    Fused eligibility beyond the vision family's: no qk_norm, no GQA
    (num_kv_heads == num_heads — the fused grid is one head per step),
    shared 1-D positions, even head_dim (RoPE halves), and fp32
    activations unless quantized (the sequential path's plain ``nn.
    linear`` accumulates in the activation dtype; the kernel accumulates
    fp32, which only coincides bitwise when they agree).
    """
    engine = engine if engine is not None else get_engine()
    from repro.core.attention import spiking_attention  # lazy: no cycle
    from repro.core.spiking import lif_scan
    t, b, s_len, d = h.shape
    heads, hd = cfg.num_heads, cfg.head_dim
    names = ("wq", "wk", "wv")
    quant = ["qw" in p[w] for w in names]
    flops = 6 * (t * b * s_len) * d * cfg.q_dim \
        + 4 * (t * b * heads) * s_len * s_len * hd
    positions = jnp.asarray(positions)
    eligible = (not cfg.qk_norm
                and cfg.num_kv_heads == cfg.num_heads
                and (all(quant) or not any(quant))
                and not any("b" in p[w] for w in names)
                and (all(quant) or h.dtype == jnp.float32)
                and hd % 2 == 0
                and positions.ndim == 1)
    if eligible and resolve_overlap(engine, h, flops) in ("fused",
                                                          "pipeline"):
        if all(quant):
            w3 = jnp.stack([_unpacked_qw(p[w], d) for w in names]
                           ).astype(h.dtype)
            scale3 = jnp.stack([p[w]["scale"].astype(jnp.float32)
                                for w in names])
        else:
            w3 = jnp.stack([p[w]["w"] for w in names])
            scale3 = None
        half = hd // 2
        # nn.rope's table, verbatim (same f32 expression -> same values)
        freqs = cfg.rope_theta ** (
            -jnp.arange(0, half, dtype=jnp.float32) / half)
        ang = positions.astype(jnp.float32)[:, None] * freqs
        aux = jnp.stack([jnp.cos(ang), jnp.sin(ang)])
        spec = _BundleSpec("rope", heads, hd, 1.0 / math.sqrt(hd), True,
                           cfg.spiking, 1e-5, engine.interpret)
        with annotate("dual_engine.fused_ssa"):
            ctx = _fused_bundle(h, w3, scale3, aux, p["delta"], spec)
        return ctx
    # sequential composition (what models/transformer.apply_layer used to
    # inline for the spiking full-attention branch)
    from repro.models.transformer import _project_qkv  # lazy: no cycle
    q, k, v = _project_qkv(p, cfg, h, positions, repeat_kv=True)
    q, k, v = (lif_scan(u, cfg.spiking)[0] for u in (q, k, v))
    fold = lambda u: u.reshape(-1, *u.shape[2:])     # (T*B, S, H, hd)
    swap = lambda u: u.transpose(0, 2, 1, 3)
    ctx = spiking_attention(swap(fold(q)), swap(fold(k)), swap(fold(v)),
                            cfg.spiking, delta_score=p["delta"],
                            causal=True)
    return swap(ctx).reshape(t, b, s_len, cfg.q_dim)


# ---------------------------------------------------------------------------
# fused whole-layer step (overlap='fused'|'pipeline'): the layer program —
# SSA bundle + output projection + residuals + spiking MLP — runs as one
# Pallas grid (kernels/fused_layer.py) with the decoded gather datapath
# available inside the projection phases and a per-phase occupancy map for
# the binary engine. Custom VJP recomputes the sequential oracle in bwd.
# ---------------------------------------------------------------------------


class _LayerSpec(NamedTuple):
    """Static (hashable) closure of a layer-program step — the nondiff
    arg of the custom VJP, shared verbatim by the fwd (kernel or oracle)
    and the oracle bwd (the PR 6 ``_BundleSpec`` pattern, extended with
    the layer plan)."""
    family: str
    num_heads: int
    head_dim: int
    scale: float
    causal: bool
    scfg: Any                   # SpikingConfig (frozen dataclass)
    eps: float
    norm_eps: float
    overlap: str                # off | fused | pipeline
    sparse: str                 # tile | decoded
    l_block: int
    c_block: int
    interpret: Optional[bool]


def _layer_kernel_args(ops, spec):
    return ((ops["x"], ops["s"], ops["w3"], ops["wo"], ops["w1"],
             ops["w2"], ops["scales"], ops["auxp"], ops["auxo"],
             ops["aux1"], ops["aux2"], ops["delta"]),
            dict(family=spec.family, num_heads=spec.num_heads,
                 head_dim=spec.head_dim, scale=spec.scale,
                 causal=spec.causal, eps=spec.eps,
                 norm_eps=spec.norm_eps))


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _fused_layer(ops, spec):
    """Every *eligible* layer runs through this step — also with
    ``overlap='off'``, where the fwd is the sequential oracle itself.
    One function means one gradient program for all overlap modes (the
    same bwd jaxpr below), which is what makes off/fused/pipeline
    gradients bitwise-identical *by construction*: an inline-autodiff
    bwd and a recompute bwd are different jaxprs computing the same
    math, and XLA's FMA contraction resolves them differently at the
    one-ulp level once the layer scan splits fwd and bwd into separate
    compiled programs."""
    if spec.overlap == "off":
        from repro.kernels.fused_layer import reference_layer  # lazy
        args, kw = _layer_kernel_args(ops, spec)
        return reference_layer(*args, spec.scfg, **kw)
    from repro.kernels.fused_layer import fused_layer  # lazy: no cycle
    args, kw = _layer_kernel_args(ops, spec)
    out, _ = fused_layer(
        *args, sparse=spec.sparse, pipeline=spec.overlap == "pipeline",
        binarize_scores=spec.scfg.binarize_scores, decay=spec.scfg.decay,
        v_th=spec.scfg.v_threshold, soft_reset=spec.scfg.soft_reset,
        l_block=spec.l_block, c_block=spec.c_block,
        interpret=spec.interpret, **kw)
    return out


def _layer_fwd(ops, spec):
    return _fused_layer(ops, spec), ops


def _layer_bwd(spec, res, g):
    """Recompute-through-the-oracle bwd (the PR 6 pattern): differentiate
    ``kernels.fused_layer.reference_layer`` — the sequential layer
    composition the kernel is pinned against bitwise — so the fused path
    returns exactly the sequential path's gradients, surrogate LIF /
    binarize jvps included. Quantized int codes are cast to the
    activation dtype before this boundary; d_ff zero-padding happens
    outside it, so pad cotangents slice back automatically."""
    from repro.kernels.fused_layer import reference_layer  # lazy

    def f(o):
        args, kw = _layer_kernel_args(o, spec)
        return reference_layer(*args, spec.scfg, **kw)

    _, vjp = jax.vjp(f, res)
    return vjp(g)


_fused_layer.defvjp(_layer_fwd, _layer_bwd)


def _layer_quant_w3(p, names, d, dtype):
    """(stacked qkv weights, per-proj scales) for an all-quantized layer."""
    w3 = jnp.stack([_unpacked_qw(p[w], d) for w in names]).astype(dtype)
    scale3 = jnp.stack([p[w]["scale"].astype(jnp.float32) for w in names])
    return w3, scale3


def _layer_linear(p, k, dtype):
    """(weight codes cast to activation dtype, fp32 scale-or-ones) for one
    layer linear — quantized or native."""
    if "qw" in p:
        return _unpacked_qw(p, k).astype(dtype), \
            p["scale"].astype(jnp.float32)
    return p["w"], jnp.ones((p["w"].shape[-1],), jnp.float32)


def _pad_ff(w1, w2, sc1, aux1, heads):
    """Zero-pad d_ff to a multiple of ``num_heads`` (the fused grid hands
    each head one ff-chunk). Exact: padded up-columns are zero, so the
    padded channels carry zero current, normalize to zero through the
    identity BN rows appended to aux1 ([mean 0, var 1, scale 1, bias 0]),
    never cross the LIF threshold (v_th > 0), and meet zero down-rows."""
    ff = w1.shape[1]
    pad = (-ff) % heads
    if pad == 0:
        return w1, w2, sc1, aux1
    w1 = jnp.pad(w1, ((0, 0), (0, pad)))
    w2 = jnp.pad(w2, ((0, pad), (0, 0)))
    sc1 = jnp.pad(sc1, (0, pad), constant_values=1.0)
    if aux1 is not None:
        ident = jnp.tile(jnp.asarray([0.0, 1.0, 1.0, 0.0],
                                     jnp.float32)[:, None], (1, pad))
        aux1 = jnp.concatenate([aux1, ident], axis=1)
    return w1, w2, sc1, aux1


def _bn_rows(p, st, name):
    return jnp.stack([st[name]["mean"].astype(jnp.float32),
                      st[name]["var"].astype(jnp.float32),
                      p[name]["scale"].astype(jnp.float32),
                      p[name]["bias"].astype(jnp.float32)])


def layer_step(p: Dict[str, Any], st: Dict[str, Any], cfg, x: jax.Array,
               *, train: bool = False,
               engine: Optional[EngineConfig] = None):
    """The vision-family *layer program*: input LIF + SSA bundle + output
    projection (wo + bn_o) + pre-neuron residual + spiking MLP (w1 +
    bn_1 + LIF + w2 + bn_2) + residual, as one engine-owned step.
    ``models/spikingformer._block`` hands the whole encoder layer here.

    p/st: the block param/state subtrees (_block_init/_block_state
    layout); x: (T, B, L, D) membrane currents (the residual stream);
    cfg: ModelConfig. Returns (y (T, B, L, D), new BN state).

    With ``overlap='fused' | 'pipeline'`` (and an eligible layer) the
    whole program runs as one Pallas grid — kernels/fused_layer.py, with
    ``sparse='decoded'`` composing the gather-compacted projection
    datapath into the fused phases (resolve_layer_plan). Eligibility
    follows the PR 6 static-fallback discipline: eval only (train-mode
    BN needs global batch stats), bias-free linears, all-or-none
    quantization, binarized scores with analog context (the blocked
    binary phases and the head-split wo contraction stay exact on
    integer contexts). Eligible layers route through the shared
    custom-VJP step for *every* overlap mode — ``overlap='off'`` runs
    the sequential oracle as its fwd — so off/fused/pipeline agree
    bitwise on gradients by construction (see ``_fused_layer``).
    Ineligible layers (and train mode) run the plain sequential
    composition below, which still hands the SSA bundle to
    :func:`ssa_step`, so bundle-level fusion survives a layer-level
    fallback.
    """
    engine = engine if engine is not None else get_engine()
    from repro.core.spiking import lif_scan
    from repro.models import nn
    t, b, l, d = x.shape
    heads, hd = cfg.num_heads, cfg.head_dim
    lin_names = ("wq", "wk", "wv", "wo", "w1", "w2")
    quant = ["qw" in p[w] for w in lin_names]
    flops = 6 * (t * b * l) * d * cfg.q_dim \
        + 4 * (t * b * heads) * l * l * hd \
        + 2 * (t * b * l) * cfg.q_dim * d \
        + 4 * (t * b * l) * d * cfg.d_ff
    eligible = (not train
                and (all(quant) or not any(quant))
                and not any("b" in p[w] for w in lin_names)
                and cfg.spiking.binarize_scores
                and not cfg.spiking.binarize_context)
    s = lif_scan(x, cfg.spiking)[0]
    plan = resolve_layer_plan(engine, s, flops)
    if eligible:
        dtype = x.dtype
        if all(quant):
            w3, sc3 = _layer_quant_w3(p, ("wq", "wk", "wv"), d, dtype)
        else:
            w3 = jnp.stack([p[w]["w"] for w in ("wq", "wk", "wv")])
            sc3 = jnp.ones((3, cfg.q_dim), jnp.float32)
        wo, sco = _layer_linear(p["wo"], cfg.q_dim, dtype)
        w1, sc1 = _layer_linear(p["w1"], d, dtype)
        w2, sc2 = _layer_linear(p["w2"], cfg.d_ff, dtype)
        aux1 = _bn_rows(p, st, "bn_1")
        w1, w2, sc1, aux1 = _pad_ff(w1, w2, sc1, aux1, heads)
        ops = {
            "x": x, "s": s, "w3": w3, "wo": wo, "w1": w1, "w2": w2,
            "scales": (sc3, sco, sc1, sc2),
            "auxp": jnp.stack([_bn_rows(p, st, f"bn_{n}")
                               for n in ("q", "k", "v")]),
            "auxo": _bn_rows(p, st, "bn_o"),
            "aux1": aux1, "aux2": _bn_rows(p, st, "bn_2"),
            "delta": p["delta"],
        }
        spec = _LayerSpec("bn", heads, hd, 1.0 / math.sqrt(hd), False,
                          cfg.spiking, 1e-5, 1e-6, plan.overlap,
                          plan.sparse,
                          engine.block_m if engine else 128,
                          engine.block_k if engine else 128,
                          engine.interpret if engine else None)
        with annotate("dual_engine.fused_layer"):
            y = _fused_layer(ops, spec)
        return y, dict(st)
    # sequential composition (what models/spikingformer._block used to
    # inline) — the reference the fused path is pinned against bitwise.
    # The bundle still routes through ssa_step: a layer-level fallback
    # keeps bundle-level fusion.
    ctx, new_st = ssa_step(p, {n: st[n] for n in ("bn_q", "bn_k", "bn_v")},
                           cfg, s, train=train, engine=engine)
    new_st = dict(st, **new_st)
    # ctx is binarized-attention output: sparse integer counts, not {0,1}
    # spikes — but zero blocks are zero blocks, so the sparse engine
    # skips them all the same. counts=True: under quantized weights the
    # counts (up to L) must ride int32 lanes, not the spikes' int8 path.
    out = nn.linear(p["wo"], ctx, spikes=True, counts=True)
    out, bn_st = nn.batchnorm(p["bn_o"], st["bn_o"],
                              out.reshape(-1, d), train=train)
    new_st["bn_o"] = bn_st
    x = x + out.reshape(t, b, l, d)               # pre-neuron residual
    s2 = lif_scan(x, cfg.spiking)[0]
    h = nn.linear(p["w1"], s2, spikes=True)
    h, bn1 = nn.batchnorm(p["bn_1"], st["bn_1"],
                          h.reshape(-1, h.shape[-1]), train=train)
    new_st["bn_1"] = bn1
    h = lif_scan(h.reshape(t, b, l, cfg.d_ff), cfg.spiking)[0]
    o = nn.linear(p["w2"], h, spikes=True)
    o, bn2 = nn.batchnorm(p["bn_2"], st["bn_2"],
                          o.reshape(-1, o.shape[-1]), train=train)
    new_st["bn_2"] = bn2
    return x + o.reshape(x.shape), new_st         # pre-neuron residual


def layer_step_causal(p: Dict[str, Any], cfg, x: jax.Array, positions, *,
                      train: bool = False,
                      engine: Optional[EngineConfig] = None) -> jax.Array:
    """The token-family *layer program* (causal, RoPE/rmsnorm epilogues):
    ln1 + SSA bundle + wo + residual + ln2 + spiking MLP + residual as
    one engine-owned step — the spiking full-attention branch of
    ``models/transformer.apply_layer`` hands the whole layer here.

    x: (T, B, S, D) residual-stream currents; positions: (S,). Returns
    the new residual stream (T, B, S, D).

    Fused eligibility = the bundle's (no qk_norm, no GQA, bias-free,
    all-or-none quantization, even head_dim, 1-D positions, fp32
    activations unless quantized) plus the MLP tail's: a plain
    (up, down) MLP — a gated MLP has no fused phase mapping — and
    binarized scores with analog context (integer contexts keep the
    head-split wo and the blocked binary phases exact). Eligible layers
    route through the shared custom-VJP step for every overlap mode
    (``off`` runs the sequential oracle as its fwd — one gradient
    program, see ``_fused_layer``); ineligible layers fall back to the
    plain sequential composition, which still hands the bundle to
    :func:`ssa_step_causal`.
    """
    engine = engine if engine is not None else get_engine()
    from repro.core.spiking import lif_scan
    from repro.models import nn
    from repro.parallel.sharding import constrain
    t, b, s_len, d = x.shape
    heads, hd = cfg.num_heads, cfg.head_dim
    h = nn.rmsnorm(p["ln1"], x, cfg.norm_eps)
    lin_ps = [p["wq"], p["wk"], p["wv"], p["wo"],
              p["mlp"].get("up"), p["mlp"].get("down")]
    quant = ["qw" in q for q in lin_ps if q is not None]
    d_ff = 0 if lin_ps[4] is None else \
        (lin_ps[4]["qw"] if "qw" in lin_ps[4] else lin_ps[4]["w"]).shape[-1]
    flops = 6 * (t * b * s_len) * d * cfg.q_dim \
        + 4 * (t * b * heads) * s_len * s_len * hd \
        + 2 * (t * b * s_len) * cfg.q_dim * d \
        + 4 * (t * b * s_len) * d * d_ff
    positions = jnp.asarray(positions)
    eligible = (not cfg.qk_norm
                and cfg.num_kv_heads == cfg.num_heads
                and set(p["mlp"]) == {"up", "down"}
                and (all(quant) or not any(quant))
                and not any(q is not None and "b" in q for q in lin_ps)
                and (all(quant) or x.dtype == jnp.float32)
                and hd % 2 == 0
                and positions.ndim == 1
                and cfg.spiking.binarize_scores
                and not cfg.spiking.binarize_context)
    plan = resolve_layer_plan(engine, h, flops)
    if eligible:
        dtype = x.dtype
        if all(quant):
            w3, sc3 = _layer_quant_w3(p, ("wq", "wk", "wv"), d, dtype)
        else:
            w3 = jnp.stack([p[w]["w"] for w in ("wq", "wk", "wv")])
            sc3 = jnp.ones((3, cfg.q_dim), jnp.float32)
        wo, sco = _layer_linear(p["wo"], cfg.q_dim, dtype)
        w1, sc1 = _layer_linear(p["mlp"]["up"], d, dtype)
        w2, sc2 = _layer_linear(p["mlp"]["down"], d_ff, dtype)
        w1, w2, sc1, _ = _pad_ff(w1, w2, sc1, None, heads)
        half = hd // 2
        # nn.rope's table, verbatim (same f32 expression -> same values)
        freqs = cfg.rope_theta ** (
            -jnp.arange(0, half, dtype=jnp.float32) / half)
        ang = positions.astype(jnp.float32)[:, None] * freqs
        ops = {
            "x": x, "s": h, "w3": w3, "wo": wo, "w1": w1, "w2": w2,
            "scales": (sc3, sco, sc1, sc2),
            "auxp": jnp.stack([jnp.cos(ang), jnp.sin(ang)]),
            "auxo": p["ln2"]["scale"].astype(jnp.float32).reshape(1, d),
            "aux1": None, "aux2": None,
            "delta": p["delta"],
        }
        spec = _LayerSpec("rope", heads, hd, 1.0 / math.sqrt(hd), True,
                          cfg.spiking, 1e-5, cfg.norm_eps, plan.overlap,
                          plan.sparse,
                          engine.block_m if engine else 128,
                          engine.block_k if engine else 128,
                          engine.interpret if engine else None)
        with annotate("dual_engine.fused_layer"):
            y = _fused_layer(ops, spec)
        return constrain(y, "batch", "seq", "embed")
    # sequential composition (what models/transformer.apply_layer used
    # to inline for the spiking full-attention branch); the bundle still
    # routes through ssa_step_causal
    attn = ssa_step_causal(p, cfg, h, positions, train=train,
                           engine=engine)
    attn = constrain(attn, "batch", "seq", "model")
    x = x + nn.linear(p["wo"], attn)
    h2 = nn.rmsnorm(p["ln2"], x, cfg.norm_eps)
    up = nn.linear(p["mlp"]["up"], h2)
    hidden = lif_scan(up, cfg.spiking)[0]
    x = x + nn.linear(p["mlp"]["down"], hidden)
    return constrain(x, "batch", "seq", "embed")
