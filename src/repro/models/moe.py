"""Mixture-of-Experts decoder LM (deepseek-moe-16b, kimi-k2-1t-a32b).

Expert parallelism strategy (DESIGN.md §6): tokens are batch-sharded over
('pod','data') and *replicated* over 'model'; experts are sharded over
'model'. Each model-shard computes its local experts' contribution for all
of its tokens via **sort-based capacity dispatch** (argsort by expert id →
capacity-bounded gather → batched expert matmul → scatter-add), then a
psum over 'model' combines contributions. No all-to-all, no one-hot
dispatch matmuls (which are FLOP-hostile at 384 experts).

Dispatch runs inside ``shard_map`` when a mesh context is installed
(launch layer), and falls back to the identical single-shard code path
otherwise (unit tests).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, MoEConfig
from repro.core.spiking import lif_scan
from repro.parallel.sharding import constrain, get_rules
from . import nn
from .transformer import _project_qkv, _attend_full_seq, _spike

try:  # jax >= 0.4.35
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map

# ---------------------------------------------------------------------------
# Mesh context for EP (installed by the launch layer)
# ---------------------------------------------------------------------------

import threading

_ctx = threading.local()


def set_ep_mesh(mesh, token_axes=("pod", "data"), expert_axis="model"):
    _ctx.mesh = mesh
    _ctx.token_axes = token_axes
    _ctx.expert_axis = expert_axis


def clear_ep_mesh():
    _ctx.mesh = None


def get_ep_mesh():
    return getattr(_ctx, "mesh", None), \
        getattr(_ctx, "token_axes", ("pod", "data")), \
        getattr(_ctx, "expert_axis", "model")


class use_ep_mesh:
    def __init__(self, mesh, token_axes=("pod", "data"), expert_axis="model"):
        self.args = (mesh, token_axes, expert_axis)

    def __enter__(self):
        self.prev = get_ep_mesh()
        set_ep_mesh(*self.args)

    def __exit__(self, *exc):
        set_ep_mesh(*self.prev)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _moe_ffn_init(key, cfg: ModelConfig):
    m = cfg.moe
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    e, d, f = m.num_experts, cfg.d_model, m.d_ff_expert
    std = 1.0 / math.sqrt(d)
    p = {
        "router": nn.normal(ks[0], (d, e), std, jnp.float32),
        "up": nn.normal(ks[1], (e, d, f), std, dt),
        "gate": nn.normal(ks[2], (e, d, f), std, dt),
        "down": nn.normal(ks[3], (e, f, d), 1.0 / math.sqrt(f), dt),
    }
    if m.num_shared:
        p["shared"] = nn.mlp_init(ks[4], d, m.num_shared * f, gated=True,
                                  dtype=dt)
    return p


def _attn_init(key, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    p = {
        "ln1": nn.rmsnorm_init(cfg.d_model, dt),
        "wq": nn.linear_init(ks[0], cfg.d_model, cfg.q_dim, dtype=dt),
        "wk": nn.linear_init(ks[1], cfg.d_model, cfg.kv_dim, dtype=dt),
        "wv": nn.linear_init(ks[2], cfg.d_model, cfg.kv_dim, dtype=dt),
        "wo": nn.linear_init(ks[3], cfg.q_dim, cfg.d_model,
                             std=1.0 / math.sqrt(cfg.q_dim * 2 * cfg.num_layers),
                             dtype=dt),
        "ln2": nn.rmsnorm_init(cfg.d_model, dt),
    }
    if cfg.spiking is not None:
        p["delta"] = jnp.asarray(cfg.spiking.attn_threshold_init, jnp.float32)
    return p


def _moe_layer_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    p = _attn_init(k1, cfg)
    p["moe"] = _moe_ffn_init(k2, cfg)
    return p


def _dense_layer_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    p = _attn_init(k1, cfg)
    p["mlp"] = nn.mlp_init(k2, cfg.d_model, cfg.moe.first_dense_ff or cfg.d_ff,
                           gated=True, dtype=jnp.dtype(cfg.dtype))
    return p


def init(cfg: ModelConfig, key) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.dtype)
    m = cfg.moe
    k_embed, k_dense, k_moe, k_head = jax.random.split(key, 4)
    params: Dict[str, Any] = {
        "embed": nn.embedding_init(k_embed, cfg.vocab_size, cfg.d_model, dt),
        "final_norm": nn.rmsnorm_init(cfg.d_model, dt),
        "lm_head": nn.linear_init(k_head, cfg.d_model, cfg.vocab_size, dtype=dt),
    }
    if m.first_k_dense:
        keys = jax.random.split(k_dense, m.first_k_dense)
        params["dense_layers"] = jax.vmap(
            lambda k: _dense_layer_init(k, cfg))(keys)
    n_moe = cfg.num_layers - m.first_k_dense
    keys = jax.random.split(k_moe, n_moe)
    params["layers"] = jax.vmap(lambda k: _moe_layer_init(k, cfg))(keys)
    return params


# ---------------------------------------------------------------------------
# routing + dispatch
# ---------------------------------------------------------------------------


def router_topk(x2d: jax.Array, router_w: jax.Array, m: MoEConfig):
    """x2d: (T, D) -> (weights (T, K), idx (T, K), aux losses)."""
    logits = jnp.dot(x2d.astype(jnp.float32), router_w)      # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, m.top_k)
    if m.normalize_topk:
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # Switch-style load balance loss + router z-loss
    me = probs.mean(axis=0)                                   # (E,)
    assign = jnp.zeros_like(probs).at[
        jnp.arange(x2d.shape[0])[:, None], idx].set(1.0).mean(axis=0)
    aux_lb = m.num_experts * jnp.sum(me * assign)
    aux_z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return w.astype(jnp.float32), idx, aux_lb, aux_z


def _local_expert_ffn(xg: jax.Array, up, gate, down, act) -> jax.Array:
    """xg: (E_loc, C, D) -> (E_loc, C, D); batched expert matmuls (MXU)."""
    h = jnp.einsum("ecd,edf->ecf", xg, up,
                   preferred_element_type=xg.dtype)
    g = jnp.einsum("ecd,edf->ecf", xg, gate,
                   preferred_element_type=xg.dtype)
    h = nn.activation(act)(g) * h
    return jnp.einsum("ecf,efd->ecd", h, down,
                      preferred_element_type=xg.dtype)


def _dispatch_local(x2d, w, idx, up, gate, down, m: MoEConfig, act: str,
                    e_local: int, local_offset) -> jax.Array:
    """Sort-based capacity dispatch for the local expert slice.

    x2d (T, D); w/idx (T, K); expert weights (E_loc, ...). Tokens routed to
    non-local experts are ignored here (another shard owns them).
    """
    t, d = x2d.shape
    k = m.top_k
    cap = max(1, int(math.ceil(t * k / m.num_experts * m.capacity_factor)))

    flat_e = idx.reshape(-1)                        # (T*K,) global expert ids
    local_e = flat_e - local_offset
    is_local = (local_e >= 0) & (local_e < e_local)
    sort_key = jnp.where(is_local, local_e, e_local)
    order = jnp.argsort(sort_key, stable=True)
    sorted_e = sort_key[order]
    sorted_tok = (jnp.arange(t * k) // k)[order]
    sorted_w = w.reshape(-1)[order]

    counts = jnp.bincount(sorted_e, length=e_local + 1)[:e_local]
    offsets = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                               jnp.cumsum(counts)])[:e_local]
    slot = offsets[:, None] + jnp.arange(cap)[None, :]        # (E_loc, C)
    valid = jnp.arange(cap)[None, :] < jnp.minimum(counts, cap)[:, None]
    slot = jnp.clip(slot, 0, t * k - 1)
    tok_of_slot = sorted_tok[slot]                            # (E_loc, C)
    w_of_slot = jnp.where(valid, sorted_w[slot], 0.0)

    xg = jnp.take(x2d, tok_of_slot.reshape(-1), axis=0).reshape(
        e_local, cap, d)
    yg = _local_expert_ffn(xg, up, gate, down, act)
    out = jnp.zeros((t, d), jnp.float32)
    out = out.at[tok_of_slot.reshape(-1)].add(
        (yg.astype(jnp.float32) * w_of_slot[..., None]).reshape(-1, d))
    return out.astype(x2d.dtype)


def moe_ffn(p, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (..., S, D) -> (y, aux_loss). EP via shard_map when mesh is set."""
    m = cfg.moe
    lead = x.shape[:-1]
    mesh, token_axes, expert_axis = get_ep_mesh()

    def run(x_loc, router_w, up, gate, down, *, e_local, offset, in_map):
        x2d = x_loc.reshape(-1, x_loc.shape[-1])
        w, idx, aux_lb, aux_z = router_topk(x2d, router_w, m)
        y = _dispatch_local(x2d, w, idx, up, gate, down, m, cfg.act,
                            e_local, offset)
        aux = m.router_aux_weight * aux_lb + m.router_z_weight * aux_z
        if in_map:
            # combine expert contributions across the EP axis in bf16 —
            # halves the dominant model-axis all-reduce (§Perf K1)
            y = jax.lax.psum(y.astype(x_loc.dtype), expert_axis)
            axes = tuple(a for a in token_axes if a in mesh.axis_names)
            if axes:
                aux = jax.lax.pmean(aux, axes)
        return y.reshape(x_loc.shape), aux

    if mesh is None:
        y, aux = run(x, p["router"], p["up"], p["gate"], p["down"],
                     e_local=m.num_experts, offset=0, in_map=False)
    else:
        ep_size = mesh.shape[expert_axis]
        e_local = m.num_experts // ep_size
        tok_spec = P(tuple(a for a in token_axes if a in mesh.axis_names),
                     *([None] * (x.ndim - 1)))

        def mapped(x_loc, router_w, up, gate, down):
            offset = jax.lax.axis_index(expert_axis) * e_local
            return run(x_loc, router_w, up, gate, down,
                       e_local=e_local, offset=offset, in_map=True)

        y, aux = shard_map(
            mapped, mesh=mesh,
            in_specs=(tok_spec, P(), P(expert_axis), P(expert_axis),
                      P(expert_axis)),
            out_specs=(tok_spec, P()),
            check_vma=False,
        )(x, p["router"], p["up"], p["gate"], p["down"])

    if m.num_shared:
        y = y + nn.mlp(p["shared"], x, cfg.act)
    return y, aux


# ---------------------------------------------------------------------------
# layers / forward / decode
# ---------------------------------------------------------------------------


def _attn_block(p, cfg: ModelConfig, x, positions, train: bool):
    h = nn.rmsnorm(p["ln1"], x, cfg.norm_eps)
    q, k, v = _project_qkv(p, cfg, h, positions, repeat_kv=True)
    if cfg.spiking is not None:
        t = x.shape[0]
        q, k, v = (_spike(u, cfg, t) for u in (q, k, v))
        fold = lambda u: u.reshape(-1, *u.shape[2:])
        attn = _attend_full_seq(cfg, "full", fold(q), fold(k), fold(v),
                                delta=p["delta"])
        attn = attn.reshape(*x.shape[:-1], cfg.q_dim)
    else:
        attn = _attend_full_seq(cfg, "full", q, k, v)
        attn = attn.reshape(*x.shape[:-1], cfg.q_dim)
    return x + nn.linear(p["wo"], constrain(attn, "batch", "seq", "model"))


def _moe_layer(p, cfg: ModelConfig, x, positions, train: bool):
    x = _attn_block(p, cfg, x, positions, train)
    h = nn.rmsnorm(p["ln2"], x, cfg.norm_eps)
    y, aux = moe_ffn(p["moe"], h, cfg)
    # name the expert output so the remat policy can SAVE it: recomputing
    # the expert FFN in bwd would re-gather the FSDP-sharded expert
    # weights a 3rd time (§Perf K4)
    y = checkpoint_name(y, "moe_out")
    return constrain(x + y, "batch", "seq", "embed"), aux


def _dense_layer(p, cfg: ModelConfig, x, positions, train: bool):
    x = _attn_block(p, cfg, x, positions, train)
    h = nn.rmsnorm(p["ln2"], x, cfg.norm_eps)
    return constrain(x + nn.mlp(p["mlp"], h, cfg.act), "batch", "seq", "embed")


def forward(params, cfg: ModelConfig, batch, *, train: bool = False,
            inputs_embeds: Optional[jax.Array] = None):
    tokens = batch["tokens"]
    x = nn.embed(params["embed"], tokens) if inputs_embeds is None \
        else inputs_embeds
    x = constrain(x, "batch", "seq", "embed")
    positions = jnp.arange(x.shape[-2])
    if cfg.spiking is not None:
        x = jnp.broadcast_to(x[None], (cfg.spiking.time_steps,) + x.shape)

    dense_fn, moe_fn = _dense_layer, _moe_layer
    if cfg.remat and train:
        dense_fn = jax.checkpoint(dense_fn, static_argnums=(1, 4),
                                  policy=jax.checkpoint_policies.nothing_saveable)
        moe_fn = jax.checkpoint(
            moe_fn, static_argnums=(1, 4),
            policy=jax.checkpoint_policies.save_only_these_names("moe_out"))

    if cfg.moe.first_k_dense:
        def dbody(x, lp):
            return dense_fn(lp, cfg, x, positions, train), None
        x, _ = jax.lax.scan(dbody, x, params["dense_layers"])

    def body(x, lp):
        x, aux = moe_fn(lp, cfg, x, positions, train)
        return x, aux
    x, auxes = jax.lax.scan(body, x, params["layers"])

    if cfg.spiking is not None:
        x = x.mean(axis=0)
    x = nn.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = nn.linear(params["lm_head"], x).astype(jnp.float32)
    return constrain(logits, "batch", "seq", "vocab"), \
        {"moe_aux": jnp.sum(auxes)}


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int,
               batch=None, params=None) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.dtype)
    b = batch_size * (cfg.spiking.time_steps if cfg.spiking else 1)

    def kv(n_layers):
        return {
            "k": jnp.zeros((n_layers, b, max_len, cfg.num_kv_heads,
                            cfg.head_dim), dt),
            "v": jnp.zeros((n_layers, b, max_len, cfg.num_kv_heads,
                            cfg.head_dim), dt),
            "pos": jnp.full((n_layers, max_len), -1, jnp.int32),
        }
    cache = {"layers": kv(cfg.num_layers - cfg.moe.first_k_dense)}
    if cfg.moe.first_k_dense:
        cache["dense_layers"] = kv(cfg.moe.first_k_dense)
    return cache


def _decode_attn(p, cfg: ModelConfig, x, cache_l, pos):
    h = nn.rmsnorm(p["ln1"], x, cfg.norm_eps)
    q, k, v = _project_qkv(p, cfg, h, jnp.full((1,), pos))
    s_len = cache_l["k"].shape[1]
    slot = pos % s_len
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache_l["k"], k, slot, 1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache_l["v"], v, slot, 1)
    entry_pos = jax.lax.dynamic_update_slice_in_dim(
        cache_l["pos"], jnp.full((1,), pos, jnp.int32), slot, 0)
    attn = nn.decode_attention(q, k_cache, v_cache, entry_pos=entry_pos,
                               cur_pos=pos)
    x = x + nn.linear(p["wo"], attn.reshape(x.shape[0], 1, cfg.q_dim))
    return x, {"k": k_cache, "v": v_cache, "pos": entry_pos}


def decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    x = nn.embed(params["embed"], tokens)
    x = constrain(x, "batch", None, "embed")
    new_cache = {}

    if cfg.moe.first_k_dense:
        def dbody(x, inp):
            lp, c = inp
            x, nc = _decode_attn(lp, cfg, x, c, pos)
            h = nn.rmsnorm(lp["ln2"], x, cfg.norm_eps)
            x = x + nn.mlp(lp["mlp"], h, cfg.act)
            return x, nc
        x, nd = jax.lax.scan(dbody, x,
                             (params["dense_layers"], cache["dense_layers"]))
        new_cache["dense_layers"] = nd

    def body(x, inp):
        lp, c = inp
        x, nc = _decode_attn(lp, cfg, x, c, pos)
        h = nn.rmsnorm(lp["ln2"], x, cfg.norm_eps)
        y, _ = moe_ffn(lp["moe"], h, cfg)
        return x + y, nc
    x, nl = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
    new_cache["layers"] = nl

    x = nn.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = nn.linear(params["lm_head"], x).astype(jnp.float32)
    return logits, new_cache
