"""Load-balancing simulator: crossbar-banked vs unified wide bank (paper
§IV-A2 and §V-C, Fig. 7 / Fig. 13B-C).

Crossbar baseline (LoAS-style [22]): kernel-weight channel chunks are
round-robin distributed over ``B_m`` banks of width W. Each of the
``P = P_Ts x P_Fx`` PEs walks its own spike bitmap; for every chunk with a
non-zero it must fetch that chunk from bank ``chunk % B_m``. Per cycle a
bank serves ONE address (PEs requesting the same bank+address share the
grant — broadcast); different addresses on the same bank serialize.
Because all PEs process the *same* kernel window over different pixels,
weight reuse makes conflicts systematic as P grows.

Ours: ONE bank of width ``B_m x W`` broadcasts chunk ``j`` to all PEs
simultaneously; each PE extracts its non-zeros with decoder throughput G
(Observation 1: per-chunk popcounts are nearly uniform across the grid, so
the broadcast rarely stalls; Observation 2: one wide vector beats several
narrow ones). Advance when the slowest PE finishes:
``cycles_j = max_pe max(1, ceil(pc[pe, j] / G))``.

Beyond the paper figures, :func:`bucket_schedule` /
:func:`predicted_schedule` model the production decoded datapath
(``kernels/spike_decode.py``): the same max-of-the-group advance rule,
restated as MXU grid steps over pow2 occupancy buckets, cross-validated
against the measured kernel schedule by the dual-engine bench.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np


def spike_chunks(n_pes: int, n_chunks: int, chunk_bits: int, sparsity: float,
                 rng: np.random.Generator,
                 grid_std_frac: float = 0.03) -> np.ndarray:
    """Popcount per (PE, chunk) under **Observation 1**: sparsity within a
    kernel window is stable across the P_Ts x P_Fx grid — the paper
    measures a cross-grid standard deviation of ~3% of the theoretical
    maximum (Fig. 7B). We model a shared per-chunk base popcount plus
    small per-PE jitter with that std."""
    base = rng.binomial(chunk_bits, 1.0 - sparsity, size=n_chunks)
    jitter = rng.normal(0.0, grid_std_frac * chunk_bits,
                        size=(n_pes, n_chunks))
    pc = np.clip(np.rint(base[None, :] + jitter), 0, chunk_bits)
    return pc.astype(np.int64)


def crossbar_latency(pc: np.ndarray, n_banks: int, throughput: int,
                     max_share: int = 8) -> int:
    """Cycle-accurate crossbar sim (Fig. 7A baseline, LoAS-style [22]).

    pc: (P, n_chunks) popcounts. Each PE walks its bitmap in chunk order;
    extracting the non-zeros of chunk ``j`` takes ``ceil(pc/G)`` cycles and
    the PE must hold a grant from bank ``j % n_banks`` on EVERY extraction
    cycle (weights stream from the bank as indices decode — the data-reuse
    pressure the paper identifies). A bank serves one address per cycle;
    PEs on the same address share the grant up to the crossbar's multicast
    fan-out ``max_share`` (modeling assumption: real all-to-all
    interconnects have bounded fan-out; 8 calibrates the paper's 70.68%
    scaling-degradation anchor to within 0.5pp — see EXPERIMENTS.md for
    the calibration table and the one anchor that deviates). Arbitration
    is oldest-first (fair), the friendliest choice for the baseline.
    """
    n_pes, n_chunks = pc.shape
    cyc_need = np.maximum(1, -(-pc // throughput))  # (P, n_chunks)
    ptr = np.zeros(n_pes, dtype=np.int64)           # current chunk per PE
    left = np.array([cyc_need[p, 0] for p in range(n_pes)])
    wait = np.zeros(n_pes, dtype=np.int64)          # age for fair arbiter
    done = np.zeros(n_pes, dtype=bool)
    cycle = 0
    while not done.all():
        # group active PEs by (bank, address)
        requests = {}
        for p in np.nonzero(~done)[0]:
            j = ptr[p]
            requests.setdefault((j % n_banks, j), []).append(p)
        # per bank: grant the address with the oldest waiting PE
        by_bank = {}
        for (bank, addr), pes in requests.items():
            age = max(wait[p] for p in pes)
            cur = by_bank.get(bank)
            if cur is None or age > cur[0]:
                by_bank[bank] = (age, addr, pes)
        granted = set()
        for bank, (_, addr, pes) in by_bank.items():
            pes = sorted(pes, key=lambda p: -wait[p])[:max_share]
            for p in pes:
                granted.add(p)
                left[p] -= 1
                wait[p] = 0
                if left[p] == 0:
                    ptr[p] += 1
                    if ptr[p] >= n_chunks:
                        done[p] = True
                    else:
                        left[p] = cyc_need[p, ptr[p]]
        for p in np.nonzero(~done)[0]:
            if p not in granted:
                wait[p] += 1
        cycle += 1
    return cycle


def unified_latency(pc: np.ndarray, throughput: int,
                    width_scale: int = 1) -> int:
    """Unified wide-bank broadcast sim.

    ``width_scale`` merges that many chunks into one broadcast word (equal
    total bandwidth to a crossbar with width_scale banks).
    """
    n_pes, n_chunks = pc.shape
    if width_scale > 1:
        pad = (-n_chunks) % width_scale
        if pad:
            pc = np.concatenate([pc, np.zeros((n_pes, pad), pc.dtype)], 1)
        pc = pc.reshape(n_pes, -1, width_scale).sum(axis=2)
    cycles = np.maximum(1, -(-pc // throughput))   # (P, n_words)
    return int(cycles.max(axis=0).sum())


# ---------------------------------------------------------------------------
# Decoded-datapath bucket schedule (the TPU translation of the unified
# wide-bank idea — kernels/spike_decode.py executes this schedule)
# ---------------------------------------------------------------------------


def _pow2ceil(x: np.ndarray) -> np.ndarray:
    x = np.asarray(x, dtype=np.int64)
    out = np.array([0 if v <= 0 else (1 if v == 1 else
                    1 << int(v - 1).bit_length()) for v in x.ravel()],
                   dtype=np.int64)
    return out.reshape(x.shape)


def bucket_schedule(occ: np.ndarray, block_m: int, c_block: int, cap: int):
    """Numpy twin of ``kernels/spike_decode.build_schedule`` — the
    predicted bucket schedule of the gather-compacted datapath.

    Same move as :func:`unified_latency`, translated to MXU grid steps:
    the unified bank advances when the slowest PE in a broadcast word
    finishes (``max_pe ceil(pc/G)``), and the decoded kernel's grid step
    covers a block_m row group whose cost is ``ceil(cap_g / c_block)``
    with ``cap_g = pow2ceil(max occupancy in group)`` — sorting rows by
    occupancy first is what keeps that max tight (the out-of-order /
    weight-dispatch analog: the densest rows share a group instead of
    straggling every group).

    occ: per-row non-zero counts; rows pad with zeros to a block_m
    multiple. Returns a dict with per-group ``caps``/``steps``, the
    ``executed``/``total`` step counts per N tile, ``mac_fraction`` =
    executed/total, and the pow2 ``buckets`` histogram {capacity:
    n_groups}. Cross-validated against the measured kernel schedule in
    ``benchmarks/dual_engine_bench.py`` and pinned equal to the jnp
    implementation in tests.
    """
    occ = np.asarray(occ, dtype=np.int64).ravel()
    pad = (-len(occ)) % block_m
    if pad:
        occ = np.concatenate([occ, np.zeros(pad, np.int64)])
    cp = max(c_block, -(-cap // c_block) * c_block)
    occ_sorted = np.sort(occ)
    gmax = occ_sorted.reshape(-1, block_m).max(axis=1)
    caps = np.minimum(_pow2ceil(gmax), cp)
    steps = -(-caps // c_block)
    nc = cp // c_block
    executed = int(steps.sum())
    total = len(gmax) * nc
    buckets = {int(c): int((caps == c).sum()) for c in np.unique(caps)}
    return {"caps": caps, "steps": steps, "executed": executed,
            "total": total, "padded_cap": cp, "buckets": buckets,
            "mac_fraction": executed / total}


def predicted_schedule(n_rows: int, k: int, density, block_m: int,
                       c_block: int, rng: np.random.Generator):
    """Predicted bucket schedule from the *density model* alone (no
    spike tensor): per-row occupancies are Binomial(k, density) with
    ``density`` a scalar (fine-grained i.i.d. firing) or per-row array
    (ragged firing). This is the sim side of the bench cross-validation;
    the measured side runs ``build_schedule`` on the actual tensor.
    """
    d = np.broadcast_to(np.asarray(density, dtype=np.float64), (n_rows,))
    occ = rng.binomial(k, d)
    return bucket_schedule(occ, block_m, c_block, cap=k)


def binary_block_schedule(k_spk: np.ndarray, v_spk: np.ndarray,
                          num_heads: int, l_block: int, delta: float,
                          binarize: bool = True) -> np.ndarray:
    """Numpy twin of the fused-layer kernel's **binary-engine** occupancy
    map (``kernels/fused_layer``, phases ``qkt``/``qktv``).

    The kernel skips a score block when its key L-block is all dark
    (zeros score to zeros, which binarize to zero for ``delta > 0``) and
    a context block when additionally its value L-block is all dark —
    the binary-engine analog of the sparse side's tile skip. This twin
    predicts the executed sub-block counts from the projection spikes
    alone, with the same predicate:

      ``qkt[h, lb]  = #{(t, b) : any(k_blk) or delta <= 0}``
      ``qktv[h, lb] = #{(t, b) : qkt live and any(v_blk)}``

    (``binarize=False`` makes every qkt block live — analog scores of a
    dark key block are still exact zeros, but the kernel only skips when
    the binarized block is provably dark.)

    k_spk / v_spk: ``(T, B, L, num_heads * head_dim)`` spike tensors as
    the projection phases emit them. Returns ``(num_heads, 2,
    n_l_blocks)`` int64 counts, cross-validated sub-block-exact against
    the kernel's ``counts[:, 3:5, :]`` by the dual-engine bench.
    """
    k_spk = np.asarray(k_spk)
    v_spk = np.asarray(v_spk)
    t, b, l, q_dim = k_spk.shape
    hd = q_dim // num_heads
    nlb = -(-l // l_block)
    out = np.zeros((num_heads, 2, nlb), np.int64)
    for h in range(num_heads):
        ks = k_spk[..., h * hd:(h + 1) * hd]
        vs = v_spk[..., h * hd:(h + 1) * hd]
        for lb in range(nlb):
            r0, r1 = lb * l_block, min(l, (lb + 1) * l_block)
            k_live = ks[:, :, r0:r1].any(axis=(2, 3))
            if not binarize or delta <= 0:
                k_live = np.ones_like(k_live)
            v_live = k_live & vs[:, :, r0:r1].any(axis=(2, 3))
            out[h, 0, lb] = int(k_live.sum())
            out[h, 1, lb] = int(v_live.sum())
    return out


@dataclass(frozen=True)
class BalanceResult:
    crossbar_cycles: int
    unified_cycles: int

    @property
    def speedup(self) -> float:
        return self.crossbar_cycles / self.unified_cycles


def compare(n_pes: int = 16, n_banks: int = 4, throughput: int = 4,
            n_chunks: int = 512, chunk_bits: int = 16,
            sparsity: float = 0.75, seed: int = 0,
            match_bandwidth: bool = True) -> BalanceResult:
    """Fig. 13B point: crossbar with ``n_banks`` banks vs our single bank
    scaled to the same total bandwidth (width_scale = n_banks)."""
    rng = np.random.default_rng(seed)
    pc = spike_chunks(n_pes, n_chunks, chunk_bits, sparsity, rng)
    xb = crossbar_latency(pc, n_banks, throughput)
    ours = unified_latency(pc, throughput,
                           width_scale=n_banks if match_bandwidth else 1)
    return BalanceResult(xb, ours)


def scaling_curve(pe_counts=(1, 2, 4, 8, 16, 32, 64, 128),
                  n_banks: int = 8, throughput: int = 4,
                  n_chunks: int = 256, chunk_bits: int = 16,
                  sparsity: float = 0.75, seed: int = 0):
    """Fig. 13C: normalized per-PE throughput vs P_Ts*P_Fx for both
    schemes (1.0 at P=1). Returns (ours, crossbar) dicts."""
    ours, xbar = {}, {}
    for p in pe_counts:
        rng = np.random.default_rng(seed)
        pc = spike_chunks(p, n_chunks, chunk_bits, sparsity, rng)
        u = unified_latency(pc, throughput)
        x = crossbar_latency(pc, n_banks, throughput)
        # per-PE performance: total work fixed per PE, so 1/latency
        ours[p] = 1.0 / u
        xbar[p] = 1.0 / x
    u0, x0 = ours[pe_counts[0]], xbar[pe_counts[0]]
    return ({p: v / u0 for p, v in ours.items()},
            {p: v / x0 for p, v in xbar.items()})
