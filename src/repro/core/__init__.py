"""FireFly-T core: spiking dynamics, sparsity formats, binary attention,
and the dual-engine latency-hiding pipeline model."""
from .spiking import (SpikingConfig, spike, binarize, lif_scan, lif_step,
                      lif_loop_reference, rate_encode, direct_encode,
                      measure_sparsity)
from .attention import binary_attention_scores, spiking_attention
from .dual_engine import (EngineParallelism, AttentionWorkload,
                          required_binary_parallelism, pipeline_schedule,
                          pipeline_efficiency, complexity_reduction,
                          measured_schedule, measured_overlap_efficiency,
                          schedule_metrics, fused_step_metrics)
from . import bitpack, sparsity

__all__ = [
    "SpikingConfig", "spike", "binarize", "lif_scan", "lif_step",
    "lif_loop_reference", "rate_encode", "direct_encode", "measure_sparsity",
    "binary_attention_scores", "spiking_attention",
    "EngineParallelism", "AttentionWorkload", "required_binary_parallelism",
    "pipeline_schedule", "pipeline_efficiency", "complexity_reduction",
    "measured_schedule", "measured_overlap_efficiency",
    "schedule_metrics", "fused_step_metrics",
    "bitpack", "sparsity",
]
