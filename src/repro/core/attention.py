"""Spiking self-attention (SSA) primitives.

The binary engine's workload: given spiking ``Q, K, V`` in {0,1},

    scores  = Q @ K^T                       (AND-PopCount == binary dot)
    attn    = binarize(scores * scale, Δ_s) (binary attention, Shen et al.)
    context = attn @ V
    out     = SN(context)  or  binarize(context * scale2, Δ_o)

No softmax — which is exactly why the whole thing fuses into a single-pass
Pallas kernel with no running-max bookkeeping (see kernels/spike_attention).
This module is the pure-jnp functional form used by models; the jit'd Pallas
path is selected via ``use_kernel``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .spiking import SpikingConfig, binarize


def binary_attention_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """Integer spike-overlap counts: (..., Lq, d) x (..., Lk, d) -> (..., Lq, Lk).

    Operands are {0,1}-valued; the result equals AND-PopCount along d.
    """
    return jnp.einsum("...qd,...kd->...qk", q, k,
                      preferred_element_type=jnp.float32)


def spiking_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      cfg: SpikingConfig,
                      delta_score: jax.Array | float = 0.0,
                      scale: Optional[float] = None,
                      use_kernel: bool = False) -> jax.Array:
    """Binary spiking attention over the last two dims ``(L, d_head)``.

    Args:
      q, k, v: ``(..., L, d)`` spike tensors ({0,1} values, float dtype).
      cfg: spiking config (binarize_scores toggles binary attention vs the
        raw spiking attention of Spikformer/Spikingformer Eq. 2).
      delta_score: learnable binarization threshold Δ for the scores.
      scale: score scale; defaults to 1/sqrt(d) per Eq. 2.

    Returns:
      context ``(..., L, d)`` — binarized scores times V (membrane currents;
      the caller applies the output spiking neuron / residual).
    """
    d = q.shape[-1]
    scale = (1.0 / jnp.sqrt(d)) if scale is None else scale
    if use_kernel:
        from repro.kernels import ops as kops  # lazy: keeps core importable
        return kops.spike_attention(
            q, k, v, scale=float(scale),
            delta=delta_score, binarize_scores=cfg.binarize_scores,
            alpha=cfg.surrogate_alpha)
    scores = binary_attention_scores(q, k) * scale
    if cfg.binarize_scores:
        attn = binarize(scores, delta_score, cfg.surrogate_alpha)
    else:
        attn = scores
    return jnp.einsum("...qk,...kd->...qd", attn, v,
                      preferred_element_type=jnp.float32).astype(q.dtype)
