"""Dual-engine dispatch: per-matmul *and* per-attention engine selection.

FireFly-T's overlay couples a *sparse engine* (spike x weight projections,
zero-skipping) with a *binary engine* (QK^T / QK^T V, AND-PopCount). This
module is the orchestrator (DESIGN.md §3/§4) for both halves:

Sparse engine — every spiking matmul (Q/K/V/O projections, the MLP,
anything whose input is a {0,1} spike tensor) routes through
:func:`spike_linear`, which picks per call site between

  * ``dense``  — plain XLA dot, fp32 accumulation (the measurement
    baseline every perf PR compares against), and
  * ``sparse`` — the block-sparse ``spike_matmul`` Pallas kernel, which
    skips all-zero (block_m x block_k) spike tiles via the occupancy map
    (the MXU-granularity multi-lane decode).

Binary engine — every spiking self-attention (``core.attention.
spiking_attention``, the transformer family's spiking SSA) consults
:func:`resolve_binary_mode` for its execution target:

  * ``jnp``        — the pure-jnp reference dataflow (scores, binarize,
    context), the baseline the kernels are pinned against;
  * ``mxu_kernel`` — the fused single-pass Pallas kernel
    (``kernels/spike_attention``): {0,1} dot products on the MXU *are*
    AND-PopCount, the L x L attention matrix never leaves VMEM;
  * ``popcount``   — the literal FPGA port (``kernels/
    popcount_attention``): spikes bit-packed 32x into uint32 lanes,
    scores via VPU ``population_count``. Kept first-class to pin the
    AND-PopCount semantics and to quantify that the MXU form dominates
    on TPU (never chosen by ``auto``).

Dispatch is *static* (shape/config driven, resolved at trace time): jit
can't branch on runtime density, so ``auto`` mode uses the flop volume as
the proxy — tiny matmuls / tiny attention can't amortize kernel staging
and stay on the XLA path. The engine is installed ambiently
(thread-local, like sharding rules) by the step builders from
``ModelConfig.engine``, so model code stays free of engine plumbing.
Off-TPU the kernels run in ``interpret`` mode — the bit-exact Python
evaluation this container's tests validate against.

Both engines carry custom VJPs (dense fp32 transposes / surrogate-
gradient recompute in bwd): spike inputs come from surrogate-gradient
LIF neurons, so training steps differentiate straight through dispatch.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import threading
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Dual-engine dispatch knobs (per model, set on ModelConfig.engine).

    Sparse engine (spike x weight matmuls):
    mode: 'dense' | 'sparse' | 'auto'. 'auto' goes sparse only when the
      matmul's flop volume clears ``min_flops`` (occupancy staging and
      per-block control flow need real work to amortize — and it keeps
      CPU smoke configs on the fast XLA path).
    block_*: VMEM tile sizes of the kernel; (block_m x block_k) is also
      the skip granularity.

    Binary engine (spiking self-attention):
    binary: 'jnp' | 'mxu_kernel' | 'popcount' | 'auto'. 'auto' picks the
      fused MXU kernel when the attention flop volume (both matmuls,
      4 * BH * L^2 * d) clears ``min_flops``, else the jnp reference;
      'popcount' (the bit-packed VPU port) is only ever explicit — the
      benchmarks document that the MXU form dominates on TPU.
    attn_block_q / attn_block_k: KV-tile sizes of the attention kernels
      (non-divisible L is zero-padded inside the kernels).
    packed_kv: spiking decode caches store K/V bit-packed (uint32, the
      paper's 32x spike-RAM compression) and score against them with
      AND-PopCount; layout is static per config, so this lives here and
      not in the ambient state.

    interpret: force Pallas interpret mode (None = auto: off-TPU only).
    """
    mode: str = "auto"
    block_m: int = 128
    block_n: int = 128
    block_k: int = 128
    min_flops: int = 1 << 22
    binary: str = "auto"
    attn_block_q: int = 128
    attn_block_k: int = 128
    packed_kv: bool = True
    interpret: Optional[bool] = None

    def replace(self, **kw) -> "EngineConfig":
        return dataclasses.replace(self, **kw)


DENSE = EngineConfig(mode="dense")
SPARSE = EngineConfig(mode="sparse")

_state = threading.local()


def set_engine(engine: Optional[EngineConfig]) -> None:
    _state.engine = engine


def get_engine() -> Optional[EngineConfig]:
    return getattr(_state, "engine", None)


class use_engine:
    """Context manager installing the ambient engine (mirrors
    sharding.use_rules). ``use_engine(None)`` disables dispatch."""

    def __init__(self, engine: Optional[EngineConfig]):
        self.engine = engine

    def __enter__(self):
        self.prev = get_engine()
        set_engine(self.engine)
        return self.engine

    def __exit__(self, *exc):
        set_engine(self.prev)


def engine_scope(cfg) -> contextlib.AbstractContextManager:
    """Engine context for a model config: installs ``cfg.engine`` when the
    config sets one, otherwise leaves the ambient engine untouched (so a
    caller-installed engine survives step builders for engine-less
    configs)."""
    engine = getattr(cfg, "engine", None)
    if engine is None:
        return contextlib.nullcontext()
    return use_engine(engine)


def resolve_mode(engine: Optional[EngineConfig], m: int, k: int, n: int
                 ) -> str:
    """Static dense/sparse decision for an (M, K) x (K, N) spike matmul."""
    if engine is None:
        return "dense"
    if engine.mode in ("dense", "sparse"):
        return engine.mode
    if engine.mode != "auto":
        raise ValueError(f"unknown engine mode {engine.mode!r}")
    return "sparse" if 2 * m * k * n >= engine.min_flops else "dense"


BINARY_MODES = ("jnp", "mxu_kernel", "popcount")


def resolve_binary_mode(engine: Optional[EngineConfig], bh: int, l: int,
                        d: int) -> str:
    """Static binary-engine decision for a (BH, L, d) spiking attention.

    ``bh`` is the folded batch x heads dim; the workload is two L x L x d
    matmuls per batch entry (QK^T and attn @ V — no softmax between, see
    kernels/spike_attention). 'auto' never picks 'popcount': the MXU
    kernel dominates it on TPU (DESIGN.md §3); the popcount path is an
    explicit, semantics-pinning selection.
    """
    if engine is None:
        return "jnp"
    if engine.binary in BINARY_MODES:
        return engine.binary
    if engine.binary != "auto":
        raise ValueError(f"unknown binary engine mode {engine.binary!r}")
    return "mxu_kernel" if 4 * bh * l * l * d >= engine.min_flops else "jnp"


# ---------------------------------------------------------------------------
# sparse path: Pallas kernel fwd, dense-transpose bwd
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _sparse_matmul(s2d, w, b, block_m, block_n, block_k, interpret):
    from repro.kernels.spike_matmul import spike_matmul  # lazy: no cycle
    # keep the fp32 accumulator: spike_linear casts once to the
    # activation dtype, exactly like the dense reference — a w.dtype
    # round-trip here would break bit-parity for mixed dtypes.
    return spike_matmul(s2d, w, bias=b, block_m=block_m, block_n=block_n,
                        block_k=block_k, out_dtype=jnp.float32,
                        interpret=interpret)


def _sparse_fwd(s2d, w, b, block_m, block_n, block_k, interpret):
    out = _sparse_matmul(s2d, w, b, block_m, block_n, block_k, interpret)
    return out, (s2d, w, b)


def _sparse_bwd(block_m, block_n, block_k, interpret, res, g):
    s2d, w, b = res
    g32 = g.astype(jnp.float32)
    ds = jnp.dot(g32, w.astype(jnp.float32).T,
                 preferred_element_type=jnp.float32).astype(s2d.dtype)
    dw = jnp.dot(s2d.astype(jnp.float32).T, g32,
                 preferred_element_type=jnp.float32).astype(w.dtype)
    db = None if b is None else g32.sum(axis=0).astype(b.dtype)
    return ds, dw, db


_sparse_matmul.defvjp(_sparse_fwd, _sparse_bwd)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


def dense_spike_linear(p: Dict[str, Any], x: jax.Array) -> jax.Array:
    """The dense reference: fp32-accumulated dot + bias, cast back to the
    activation dtype — term-for-term what the sparse kernel computes.

    Operands stay in their native dtype (no hoisted upcasts — bf16 feeds
    the MXU directly and the result is cast back before any collective,
    preserving the §Perf F1 bf16 traffic); only the accumulator is fp32.
    """
    y = jnp.dot(x, p["w"], preferred_element_type=jnp.float32)
    if "b" in p:
        y = y + p["b"].astype(jnp.float32)
    return y.astype(x.dtype)


def spike_linear(p: Dict[str, Any], x: jax.Array, *,
                 engine: Optional[EngineConfig] = None) -> jax.Array:
    """Dual-engine linear layer for spike (or spike-derived sparse) inputs.

    p: {'w': (K, N)[, 'b': (N,)]} param dict (models/nn.py layout);
    x: (..., K) activations — {0,1} spikes or the sparse integer counts a
    binary-attention context carries. Leading dims fold into the sparse
    engine's M. ``engine=None`` uses the ambient engine (see use_engine);
    no ambient engine means dense.
    """
    engine = engine if engine is not None else get_engine()
    k = x.shape[-1]
    n = p["w"].shape[1]
    m = 1
    for d in x.shape[:-1]:
        m *= d
    if resolve_mode(engine, m, k, n) == "dense":
        return dense_spike_linear(p, x)
    out = _sparse_matmul(x.reshape(-1, k), p["w"], p.get("b"),
                         engine.block_m, engine.block_n, engine.block_k,
                         engine.interpret)
    return out.reshape(*x.shape[:-1], n).astype(x.dtype)
