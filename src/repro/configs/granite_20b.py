"""granite-20b [dense]: 52L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152 — llama-arch code model with multi-query attention
[arXiv:2405.04324; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b", family="dense",
    num_layers=52, d_model=6144, num_heads=48, num_kv_heads=1, head_dim=128,
    d_ff=24576, vocab_size=49152,
    attn_type="full", act="gelu", gated=False, rope_theta=10000.0,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=128, num_heads=8, num_kv_heads=1, head_dim=16,
    d_ff=256, vocab_size=512, dtype="float32", remat=False)
