"""Batched serving driver: prefill + decode loop with continuous batching.

Host-scale demonstration of the inference path (the production-mesh
version of prefill/serve_step is exercised by dryrun.py):

  * prefill: full forward over the prompt, then token-by-token decode
    against the KV cache (consistency between the two paths is pinned by
    tests/test_models.py);
  * continuous batching: a slot-based scheduler — finished sequences free
    their slot, queued requests claim it (slot state lives in the cache
    batch dim);
  * greedy sampling (argmax) for determinism;
  * spiking LMs (``--arch spikingformer-lm``) decode against a
    *bit-packed* spike KV cache (uint32 words, AND-PopCount scoring —
    the paper's 32x spike-RAM compression); the server reports the
    measured cache footprint vs the unpacked layout.
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_ARCHS, get_config
from repro.launch import steps as steps_lib
from repro.models import registry


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (L,) int32
    max_new_tokens: int
    generated: List[int] = field(default_factory=list)
    done: bool = False


class BatchedServer:
    """Slot-based continuous batching over a fixed cache batch size."""

    def __init__(self, cfg, params, slots: int, max_len: int):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.cache = registry.init_cache(cfg, slots, max_len)
        self.decode = jax.jit(steps_lib.build_serve_step(cfg),
                              static_argnums=(), donate_argnums=(1,))
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.slot_pos = np.zeros(slots, np.int32)
        self.queue: List[Request] = []
        self.completed: List[Request] = []

    def kv_cache_stats(self) -> Dict[str, float]:
        """Measured KV footprint; 'compression' is the ratio vs storing
        the same entries unpacked in the activation dtype (32x per word
        when the spiking packed-KV path is on, 1.0 otherwise)."""
        leaves = jax.tree_util.tree_leaves(self.cache)
        kv_bytes = sum(l.nbytes for l in leaves
                       if l.dtype != jnp.int32)          # skip pos tags
        act_bytes = jnp.dtype(self.cfg.dtype).itemsize
        packed = any(l.dtype == jnp.uint32 for l in leaves)
        if packed:
            words = -(-self.cfg.head_dim // 32)
            unpacked = kv_bytes // 4 // words * self.cfg.head_dim * act_bytes
        else:
            unpacked = kv_bytes
        return {"kv_bytes": kv_bytes, "packed": packed,
                "compression": unpacked / max(1, kv_bytes)}

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for s in range(self.slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.pop(0)
                self.slot_req[s] = req
                self.slot_pos[s] = 0

    def step(self):
        """One decode step for all active slots (prompt tokens are fed
        through the decode path one at a time = chunked prefill size 1)."""
        self._admit()
        active = [s for s in range(self.slots) if self.slot_req[s]]
        if not active:
            return False
        tokens = np.zeros((self.slots, 1), np.int32)
        for s in active:
            req = self.slot_req[s]
            p = int(self.slot_pos[s])
            if p < len(req.prompt):
                tokens[s, 0] = req.prompt[p]
            else:
                tokens[s, 0] = req.generated[-1]
        # NOTE: single shared position counter per batch step keeps the
        # compiled step static; slots run position-aligned per wave.
        pos = int(self.slot_pos[active[0]])
        logits, self.cache = self.decode(self.params, self.cache,
                                         jnp.asarray(tokens),
                                         jnp.asarray(pos, jnp.int32))
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for s in active:
            req = self.slot_req[s]
            self.slot_pos[s] += 1
            p = int(self.slot_pos[s])
            if p >= len(req.prompt):
                req.generated.append(int(nxt[s]))
            if len(req.generated) >= req.max_new_tokens or \
                    p >= self.max_len - 1:
                req.done = True
                self.completed.append(req)
                self.slot_req[s] = None
        return True


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-3-4b",
                    choices=list(ALL_ARCHS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    if not registry.has_decode(cfg):
        raise SystemExit(f"{args.arch} has no decode step")
    params = registry.init(cfg, jax.random.PRNGKey(0))
    server = BatchedServer(cfg, params, args.slots, args.max_len)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        server.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                       args.prompt_len).astype(np.int32),
            max_new_tokens=args.max_new))
    kv = server.kv_cache_stats()
    print(f"[serve] kv cache {kv['kv_bytes']/1024:.1f} KiB "
          f"(packed={kv['packed']}, {kv['compression']:.0f}x vs unpacked)")
    t0 = time.time()
    steps = 0
    while server.step():
        steps += 1
    dt = time.time() - t0
    n_tok = sum(len(r.generated) for r in server.completed)
    print(f"[serve] {len(server.completed)} requests, {n_tok} tokens, "
          f"{steps} decode steps in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s on CPU smoke config)")
    for r in server.completed[:3]:
        print(f"  req {r.rid}: {r.generated}")


if __name__ == "__main__":
    main()
