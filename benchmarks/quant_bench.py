"""Quantized-weight datapath sweep: dtype x shape x sparsity.

Three measurements of the ``repro.quant`` subsystem (DESIGN.md §8):

  * ``rows`` — wall clock of the sparse-engine spike matmul per weight
    dtype (fp32 reference kernel vs int8 vs int4-unpacked codes) over
    (M, K, N) x coherent tile sparsity. On CPU the kernels run in Pallas
    *interpret* mode, so wall-clock ratios measure the lowered-lax
    emulation — the transferable numbers are the footprint and the
    skip fraction (dtype-independent: occupancy skips fire identically
    on integer weights);
  * ``footprint`` — measured weight-footprint compression on the
    **full** ``spikingformer-lm`` config materialized in fp32 (the
    serving reference dtype): int8 ≈ 4K/(K+4) ≈ 3.94x at K=256, int4
    (packed nibbles) ≈ 8K/(K+8) ≈ 7.75x — the dual-side compression
    claim, measured not modeled;
  * ``calibration`` — whole-model PTQ logit deltas on the spikingformer
    smoke configs (clip-ratio grid, chosen point) — the accuracy side of
    the trade.

Output: ``artifacts/quant_bench.json``; also wired into
``benchmarks/run.py`` (CI smoke emits it on every run).

Usage: PYTHONPATH=src python benchmarks/quant_bench.py [--fast|--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from dual_engine_bench import coherent_spikes

SHAPES = [(256, 128, 256), (512, 256, 256), (1024, 256, 512)]  # (M, K, N)
SPARSITIES = [0.5, 0.75, 0.9]
BLOCK = 64
REPS = 5
DTYPES = ("fp32", "int8", "int4")


def _time(fn, *args) -> float:
    fn(*args).block_until_ready()
    ts = []
    for _ in range(REPS):
        t0 = time.perf_counter()
        fn(*args).block_until_ready()
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2] * 1e6


def kernel_rows(fast: bool = False):
    from repro.core import engine as E
    from repro.kernels.spike_matmul import block_occupancy
    from repro.quant import quantize_weight

    shapes = SHAPES[:2] if fast else SHAPES
    sparsities = SPARSITIES[1:] if fast else SPARSITIES
    eng = E.EngineConfig(mode="sparse", block_m=BLOCK, block_n=BLOCK,
                         block_k=BLOCK)
    rows = []
    for m, k, n in shapes:
        key = jax.random.PRNGKey(m + k + n)
        kw, ks = jax.random.split(key)
        w = jax.random.normal(kw, (k, n), jnp.float32) / (k ** 0.5)
        trees = {"fp32": {"w": w},
                 "int8": quantize_weight(w, "int8"),
                 "int4": quantize_weight(w, "int4")}
        for sparsity in sparsities:
            s = coherent_spikes(ks, m, k, BLOCK, sparsity)
            occ = block_occupancy(s, min(BLOCK, m), min(BLOCK, k))
            skip = float(1.0 - occ.mean())
            us = {}
            for dt in DTYPES:
                p = trees[dt]
                us[dt] = _time(jax.jit(
                    lambda s, p=p: E.spike_linear(p, s, engine=eng)), s)
            rows.append({
                "bench": "quant_linear", "shape": [m, k, n],
                "block": BLOCK, "sparsity": sparsity,
                "skip_fraction": round(skip, 4),
                "fp32_us": round(us["fp32"], 1),
                "int8_us": round(us["int8"], 1),
                "int4_us": round(us["int4"], 1),
                "int8_vs_fp32": round(us["fp32"] / us["int8"], 3),
                "int4_vs_fp32": round(us["fp32"] / us["int4"], 3),
            })
    return rows


def footprint_sweep():
    """Measured weight footprint of the full spikingformer-lm config,
    materialized in fp32 (the serving reference) and quantized."""
    from repro.configs import get_config
    from repro.models import registry
    from repro.quant import footprint_report, quantize_tree

    cfg = get_config("spikingformer-lm", smoke=False).replace(
        dtype="float32", remat=False)
    params = registry.init(cfg, jax.random.PRNGKey(0))
    out = {"config": cfg.name,
           "n_params": int(sum(l.size for l in
                               jax.tree_util.tree_leaves(params)))}
    for dt in ("int8", "int4"):
        rep = footprint_report(params, quantize_tree(params, dt))
        out[dt] = rep
    return out


def calibration_sweep(fast: bool = False):
    """Whole-model PTQ logit deltas on the spikingformer smoke configs."""
    from repro.configs import get_config
    from repro.models import registry
    from repro.quant import calibrate

    out = {}
    # token-domain spiking LM
    cfg = get_config("spikingformer-lm", smoke=True)
    params = registry.init(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16),
                                          0, cfg.vocab_size)}
    for dt in ("int8",) if fast else ("int8", "int4"):
        _, rep = calibrate(cfg, params, batch, dt)
        out[f"{cfg.name}/{dt}"] = rep
    # vision spikingformer: init scaled up so the LIF neurons fire (at
    # unit init the smoke net is silent and the comparison is vacuous)
    cfg_v = get_config("spikingformer-4-256", smoke=True)
    params_v = registry.init(cfg_v, jax.random.PRNGKey(0))
    params_v = jax.tree_util.tree_map(
        lambda a: a * 3.0 if a.ndim >= 2 else a, params_v)
    state_v = registry.init_state(cfg_v)
    batch_v = {"images": 2.0 * jax.random.normal(jax.random.PRNGKey(2),
                                                 (4, 16, 16, 3)),
               "labels": jnp.zeros((4,), jnp.int32)}
    for dt in ("int8",) if fast else ("int8", "int4"):
        _, rep = calibrate(cfg_v, params_v, batch_v, dt, state=state_v)
        out[f"{cfg_v.name}/{dt}"] = rep
    return out


def bench(fast: bool = False):
    rows = kernel_rows(fast=fast)
    fp = footprint_sweep()
    cal = calibration_sweep(fast=fast)
    med = lambda xs: sorted(xs)[len(xs) // 2]
    derived = {
        "backend": jax.default_backend(),
        "interpret_mode": jax.default_backend() != "tpu",
        "points": len(rows),
        # the acceptance numbers: measured weight-footprint compression
        # on spikingformer-lm (quantized linears vs the same linears fp32)
        "int8_compression": round(fp["int8"]["compression"], 3),
        "int4_compression": round(fp["int4"]["compression"], 3),
        "int8_total_compression": round(fp["int8"]["total_compression"], 3),
        "int4_total_compression": round(fp["int4"]["total_compression"], 3),
        "int8_logit_mae_rel": {k.split("/")[0]: round(
            v["chosen"]["logit_mae_rel"], 4)
            for k, v in cal.items() if k.endswith("int8")},
        "int8_vs_fp32_us_median": med([r["int8_vs_fp32"] for r in rows]),
        "mean_skip_at_0.9": round(sum(
            r["skip_fraction"] for r in rows if r["sparsity"] == 0.9) /
            max(1, sum(1 for r in rows if r["sparsity"] == 0.9)), 4),
    }
    return rows, {"footprint": fp, "calibration": cal, "derived": derived}


def to_blob(rows, extras):
    return {"rows": rows, **extras}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true", help="alias of --fast")
    ap.add_argument("--out", default="artifacts/quant_bench.json")
    args = ap.parse_args()
    rows, extras = bench(fast=args.fast or args.smoke)
    blob = to_blob(rows, extras)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(blob, f, indent=1)
    print("shape,sparsity,skip_fraction,fp32_us,int8_us,int4_us")
    for r in rows:
        print(f"{'x'.join(map(str, r['shape']))},{r['sparsity']},"
              f"{r['skip_fraction']},{r['fp32_us']},{r['int8_us']},"
              f"{r['int4_us']}")
    print(json.dumps(extras["derived"]))


if __name__ == "__main__":
    main()
