"""repro — FireFly-T reproduced as a multi-pod JAX training/serving framework.

Subpackages:
  core      — spiking dynamics, sparsity formats, binary attention, dual-engine model
  models    — model zoo (10 assigned architectures + Spikingformer/CIFAR-Net)
  kernels   — Pallas TPU kernels (spike attention, sparse spike matmul, LIF)
  sim       — cycle-level hardware model reproducing the paper's experiments
  data      — synthetic data pipelines
  optim     — optimizers, schedules, gradient compression
  quant     — int8/int4 weight quantization: PTQ, calibration, QAT (STE)
  checkpoint— sharded async checkpointing + elastic restore
  runtime   — fault tolerance, straggler mitigation
  parallel  — sharding rules
  configs   — per-architecture configs + input shapes
  launch    — mesh builders, dry-run driver, train/serve entry points
"""
__version__ = "1.0.0"
