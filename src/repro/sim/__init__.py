"""Cycle-level hardware model of FireFly-T (the paper's own experiments).

decoder_sim    — multi-lane sparse decoder throughput (Figs. 12, 13A)
balance_sim    — crossbar vs unified-bank load balancing (Figs. 13B, 13C)
resource_model — LUT6 AND-PopCount construction + Tables V/VI breakdown
perf_model     — end-to-end GOP/s + energy (Table IV, headline ratios)
"""
from . import balance_sim, decoder_sim, perf_model, resource_model
