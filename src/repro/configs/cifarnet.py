"""CIFAR-Net — FireFly v2's spiking conv network (Table IV footnote 3):
3x32x32-32c3-256c3-256c3-mp2-256c3-256c3-256c3-mp2-512c3-mp2-1024c3-ap-10,
T_s=4."""
from repro.core.spiking import SpikingConfig
from .base import ModelConfig, VisionSpec

CONFIG = ModelConfig(
    name="cifarnet", family="cifarnet",
    num_layers=8, d_model=1024, num_heads=1, num_kv_heads=1, head_dim=1,
    d_ff=1024, vocab_size=10,
    vision=VisionSpec(img_size=32, in_channels=3),
    spiking=SpikingConfig(time_steps=4),
)

# the conv ladder is fixed (models/spikingformer.CIFARNET_SPEC); the smoke
# config shrinks the image + time steps only.
SMOKE = CONFIG.replace(
    vision=VisionSpec(img_size=16, in_channels=3),
    spiking=SpikingConfig(time_steps=2), dtype="float32", remat=False)
