"""Cycle-level simulator of the multi-lane sparse decoder (paper §V-B).

Models a grid point of the 3D workload balancer: the orchestrator streams
``P_Ci``-bit bitmap words (one per cycle); ``P_Wo`` out-of-order workers,
each with an ``M``-lane decoder, pull words and extract non-zero indices at
up to ``M`` per cycle (a word with popcount ``pc`` occupies a worker for
``max(1, ceil(pc / M))`` cycles — the input-tracker policy).

Throughput budget ``G = P_Wo * M``. Metrics follow Eq. 6:
    R = 1 / D        (performance; D = simulated latency in cycles)
    F = 1 / (lambda * P_Ci * D^2)   (composite performance)

Reproduces Fig. 12 (optimal P_Ci ~= G / (1 - sparsity); max-F linear in
P_Ci) and Fig. 13A (R vs P_Wo at fixed G; P_Wo = 2 within >=80% of peak).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Optional

import numpy as np


@dataclass(frozen=True)
class DecoderConfig:
    p_ci: int          # input bit-width per word (channel-in parallelism)
    m_lanes: int       # decoder lanes per worker
    p_wo: int          # workers per grid point

    @property
    def throughput(self) -> int:
        return self.m_lanes * self.p_wo


def word_popcounts(total_channels: int, p_ci: int, sparsity: float,
                   rng: np.random.Generator) -> np.ndarray:
    """Popcount of each bitmap word for a workload of ``total_channels``
    channels split into P_Ci-bit words (binomial spike model)."""
    n_words = max(1, total_channels // p_ci)
    return rng.binomial(p_ci, 1.0 - sparsity, size=n_words)


def simulate_latency(popcounts: np.ndarray, cfg: DecoderConfig) -> int:
    """Discrete-event sim: words released one per cycle (orchestrator
    bandwidth), list-scheduled onto P_Wo workers (out-of-order dispatch).

    Returns the makespan in cycles.
    """
    durations = np.maximum(1, -(-popcounts // cfg.m_lanes))  # ceil div
    # workers as a min-heap of next-free times
    workers = [0] * cfg.p_wo
    heapq.heapify(workers)
    t_done = 0
    for release, dur in enumerate(durations):
        free = heapq.heappop(workers)
        start = max(free, release)          # released 1 word / cycle
        end = start + int(dur)
        heapq.heappush(workers, end)
        t_done = max(t_done, end)
    return t_done


def performance(cfg: DecoderConfig, *, sparsity: float = 0.75,
                total_channels: int = 1 << 16, seed: int = 0,
                n_trials: int = 4) -> float:
    """R = n_words / D (throughput in words per cycle, averaged)."""
    rs = []
    for trial in range(n_trials):
        rng = np.random.default_rng(seed + trial)
        pc = word_popcounts(total_channels, cfg.p_ci, sparsity, rng)
        d = simulate_latency(pc, cfg)
        rs.append(len(pc) / d)
    return float(np.mean(rs))


def latency(cfg: DecoderConfig, *, sparsity: float = 0.75,
            total_channels: int = 1 << 16, seed: int = 0) -> float:
    """D normalized per channel (cycles / channel) for Eq. 6 metrics."""
    rng = np.random.default_rng(seed)
    pc = word_popcounts(total_channels, cfg.p_ci, sparsity, rng)
    return simulate_latency(pc, cfg) / total_channels


def composite_metric(cfg: DecoderConfig, *, sparsity: float = 0.75,
                     total_channels: int = 1 << 16, seed: int = 0,
                     lam: float = 1.0) -> float:
    """Eq. 6: F = 1 / (lambda * P_Ci * D^2), D in cycles/channel."""
    d = latency(cfg, sparsity=sparsity, total_channels=total_channels,
                seed=seed)
    return 1.0 / (lam * cfg.p_ci * d * d)


def sweep_fig12(g_values=(2, 4, 8, 16), p_ci_values=(4, 8, 16, 32, 64, 128),
                sparsity: float = 0.75, seed: int = 0):
    """Fig. 12: F vs P_Ci for each throughput G (M = G, P_Wo = 1 — the
    decoder-width sweep isolates input bit-width effects).

    Returns {G: {P_Ci: F}} (F normalized to max within each G) and the
    argmax P_Ci per G.
    """
    out, best = {}, {}
    for g in g_values:
        vals = {}
        for p_ci in p_ci_values:
            if p_ci < g:
                continue
            cfg = DecoderConfig(p_ci=p_ci, m_lanes=g, p_wo=1)
            vals[p_ci] = composite_metric(cfg, sparsity=sparsity, seed=seed)
        mx = max(vals.values())
        out[g] = {k: v / mx for k, v in vals.items()}
        best[g] = max(vals, key=vals.get)
    return out, best


def sweep_fig13a(g: int, p_ci: int, sparsity: float = 0.75, seed: int = 0):
    """Fig. 13A: R vs P_Wo at fixed G (P_Wo in divisors of G)."""
    out = {}
    for p_wo in [w for w in (1, 2, 4, 8, 16) if g % w == 0 and g // w >= 1]:
        cfg = DecoderConfig(p_ci=p_ci, m_lanes=g // p_wo, p_wo=p_wo)
        out[p_wo] = performance(cfg, sparsity=sparsity, seed=seed)
    return out
