"""Config dataclasses for the model zoo and run shapes.

Every assigned architecture gets a ``configs/<id>.py`` exporting ``CONFIG``
(the exact published shape) and ``SMOKE`` (a reduced same-family config for
CPU tests). Input-shape sets live in ``configs/shapes.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.engine import EngineConfig
from repro.core.spiking import SpikingConfig


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0
    first_k_dense: int = 0          # leading dense layers (deepseek/kimi style)
    first_dense_ff: int = 0         # d_ff of those dense layers
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-3
    normalize_topk: bool = True     # renormalize top-k routing weights


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                # 0 -> ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_size: int = 64
    lora_mix: int = 32              # rank of data-dependent token-shift LoRA
    lora_decay: int = 64            # rank of data-dependent decay LoRA
    wkv_chunk: int = 0              # 0 = per-token scan; >0 = chunk-parallel
                                    # WKV (§Perf R1; exact, see models/rwkv)


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    kind: str                        # 'audio' | 'vision'
    num_embeds: int                  # frames / patches the stub provides
    embed_dim: int                   # pre-projector embedding dim
    projector_layers: int = 2        # mm projector MLP depth (vision)


@dataclasses.dataclass(frozen=True)
class VisionSpec:
    """Spikingformer / CIFAR-Net image input."""
    img_size: int = 32
    in_channels: int = 3
    sps_stages: int = 2              # maxpool stages in SPS (32->8 for CIFAR)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense|moe|rwkv|hybrid|encdec|vlm|spikingformer|cifarnet
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # attention
    attn_type: str = "full"          # full | swa | local_global
    window: int = 4096
    global_every: int = 6            # local_global: one global layer per N
    qk_norm: bool = False
    rope_theta: float = 10000.0
    # mlp
    act: str = "silu"                # silu | gelu | relu2
    gated: bool = True
    # misc
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    max_position_embeddings: int = 0  # >0 -> learned positions (whisper dec)
    # submodule configs
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    frontend: Optional[FrontendConfig] = None
    vision: Optional[VisionSpec] = None
    encoder_layers: int = 0          # whisper encoder depth
    encoder_seq: int = 1500          # whisper frame count (stubbed frontend)
    spiking: Optional[SpikingConfig] = None
    # dual-engine dispatch: step builders install this engine around the
    # forward pass, routing spike matmuls dense vs block-sparse AND
    # spiking attention jnp vs MXU-kernel vs popcount (core/engine.py).
    # The engine's packed_kv flag also selects the bit-packed spike KV
    # cache layout for spiking decode. None = always dense / jnp.
    engine: Optional[EngineConfig] = None
    dtype: str = "bfloat16"
    remat: bool = True

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim


@dataclasses.dataclass(frozen=True)
class RunShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str                        # 'train' | 'prefill' | 'decode'

    @property
    def is_decode(self) -> bool:
        return self.mode == "decode"
