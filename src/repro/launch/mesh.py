"""Production mesh builders.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — required for the dry-run's forced
512-device host platform to stay contained to launch/dryrun.py.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single-pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is
    the DCN/ICI-bridged data-parallel outer axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally, as a 1D 'data' mesh (CPU tests)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def make_serve_mesh(data: int = 1, model: int = 1):
    """Serving mesh: request slots on 'data', attention heads / vocab on
    'model'. Sized explicitly (not all-local-devices) so the serve bench
    can sweep mesh shapes under a forced host device count."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"serve mesh {data}x{model} needs {data * model} "
                         f"devices, have {n} (set XLA_FLAGS="
                         f"--xla_force_host_platform_device_count=N)")
    return jax.make_mesh((data, model), ("data", "model"))
