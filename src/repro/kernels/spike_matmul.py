"""Block-sparse spike matmul — the sparse engine's MXU adaptation.

FireFly-T's sparse engine skips zero spikes at bit granularity with
multi-lane decoders + out-of-order workers. The MXU's profitable skip
granularity is a whole VMEM tile (DESIGN.md §3): this kernel computes
``y = s @ w`` (spikes x weights) with a per-(block_m x block_k) *occupancy
bitmap* computed upfront (the block-granular analogue of the decoder's
bitmap), and skips the inner dot entirely for all-zero spike blocks via
``@pl.when`` — no weight fetch, no MACs, matching Observation 1 (sparsity
is uniform across the spatial-temporal grid, so whole-tile skips fire
often at >=75% sparsity only when channel-blocks are coherently sparse;
the occupancy reduction itself is the multi-lane decode).

Grid: (nM, nN, nK), K innermost; fp32 accumulator in the revisited output
block. The occupancy map is a tiny (nM, nK) int32 array staged per-step.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(occ_ref, s_ref, w_ref, o_ref):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(occ_ref[0, 0] > 0)
    def _compute():
        s = s_ref[...].astype(jnp.float32)
        w = w_ref[...].astype(jnp.float32)
        o_ref[...] += jax.lax.dot_general(
            s, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)


def block_occupancy(s: jax.Array, block_m: int, block_k: int) -> jax.Array:
    """(M, K) spikes -> (nM, nK) int32 any-nonzero per block."""
    m, k = s.shape
    occ = (s != 0).reshape(m // block_m, block_m, k // block_k,
                           block_k).any(axis=(1, 3))
    return occ.astype(jnp.int32)


def spike_matmul(s: jax.Array, w: jax.Array, *,
                 block_m: int = 128, block_n: int = 128, block_k: int = 128,
                 occupancy: Optional[jax.Array] = None,
                 interpret: Optional[bool] = None) -> jax.Array:
    """y = s @ w; s: (M, K) {0,1} spikes, w: (K, N) weights -> (M, N) fp32
    cast to w.dtype. Zero spike blocks are skipped."""
    m, k = s.shape
    k2, n = w.shape
    assert k == k2
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    occ = block_occupancy(s, block_m, block_k) if occupancy is None \
        else occupancy

    grid = (m // block_m, n // block_n, k // block_k)
    out = pl.pallas_call(
        functools.partial(_kernel),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((block_m, block_k), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((block_k, block_n), lambda mi, ni, ki: (ki, ni)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n),
                               lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(occ, s, w)
    return out.astype(w.dtype)
