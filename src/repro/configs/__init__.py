"""Config registry: ``--arch <id>`` resolution.

ARCHS maps every assigned architecture id (plus the paper's own models) to
its module exposing CONFIG (published shape) and SMOKE (reduced config for
CPU tests)."""
from . import (cifarnet, deepseek_moe_16b, gemma3_12b, granite_20b,
               h2o_danube3_4b, hymba_1_5b, kimi_k2_1t_a32b,
               llava_next_mistral_7b, nemotron_4_15b, rwkv6_3b, shapes,
               spikingformer_4_256, spikingformer_8_512, spikingformer_lm,
               whisper_small)
from .base import ModelConfig, RunShape
from .shapes import SHAPES

_MODULES = {
    "nemotron-4-15b": nemotron_4_15b,
    "gemma3-12b": gemma3_12b,
    "h2o-danube-3-4b": h2o_danube3_4b,
    "granite-20b": granite_20b,
    "kimi-k2-1t-a32b": kimi_k2_1t_a32b,
    "deepseek-moe-16b": deepseek_moe_16b,
    "rwkv6-3b": rwkv6_3b,
    "hymba-1.5b": hymba_1_5b,
    "whisper-small": whisper_small,
    "llava-next-mistral-7b": llava_next_mistral_7b,
    "spikingformer-4-256": spikingformer_4_256,
    "spikingformer-8-512": spikingformer_8_512,
    "spikingformer-lm": spikingformer_lm,
    "cifarnet": cifarnet,
}

ASSIGNED_ARCHS = tuple(list(_MODULES)[:10])   # the 10 assigned cells
PAPER_ARCHS = tuple(list(_MODULES)[10:])      # the paper's own models
ALL_ARCHS = tuple(_MODULES)


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    mod = _MODULES[name]
    return mod.SMOKE if smoke else mod.CONFIG


def get_shape(name: str) -> RunShape:
    return SHAPES[name]
