"""whisper-small [audio]: 12L enc + 12L dec, d_model=768 12H (MHA)
d_ff=3072 vocab=51865 — encoder-decoder; conv frontend STUBBED
(input_specs provides precomputed frame embeddings) [arXiv:2212.04356].

long_500k skipped (pure full attention, registry.NO_LONG_CONTEXT)."""
from .base import FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="encdec",
    num_layers=12, encoder_layers=12, d_model=768, num_heads=12,
    num_kv_heads=12, head_dim=64, d_ff=3072, vocab_size=51865,
    attn_type="full", act="gelu", gated=False,
    max_position_embeddings=448, encoder_seq=1500,
    frontend=FrontendConfig(kind="audio", num_embeds=1500, embed_dim=768),
)

SMOKE = CONFIG.replace(
    num_layers=2, encoder_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    head_dim=16, d_ff=128, vocab_size=512, max_position_embeddings=64,
    encoder_seq=12, dtype="float32", remat=False,
    frontend=FrontendConfig(kind="audio", num_embeds=12, embed_dim=64))
