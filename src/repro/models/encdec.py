"""Whisper-style encoder-decoder (whisper-small backbone).

Per the assignment the conv frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings (B, F, d_model) — the mel + conv1d x2 + GELU
stack is replaced by an identity over stub embeddings. Backbone is
faithful: pre-LN MHA with biases, sinusoidal encoder positions, learned
decoder positions, GELU MLP, tied decoder embedding/unembedding.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel.sharding import constrain
from . import nn


def _mha_init(key, cfg: ModelConfig, *, kv_bias: bool = False):
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    return {
        "wq": nn.linear_init(ks[0], cfg.d_model, cfg.q_dim, bias=True, dtype=dt),
        "wk": nn.linear_init(ks[1], cfg.d_model, cfg.q_dim, bias=kv_bias, dtype=dt),
        "wv": nn.linear_init(ks[2], cfg.d_model, cfg.q_dim, bias=True, dtype=dt),
        "wo": nn.linear_init(ks[3], cfg.q_dim, cfg.d_model, bias=True,
                             std=1.0 / math.sqrt(cfg.q_dim * 2 * cfg.num_layers),
                             dtype=dt),
    }


def _mlp_init(key, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    k1, k2 = jax.random.split(key)
    return {"up": nn.linear_init(k1, cfg.d_model, cfg.d_ff, bias=True, dtype=dt),
            "down": nn.linear_init(k2, cfg.d_ff, cfg.d_model, bias=True,
                                   dtype=dt)}


def _enc_layer_init(key, cfg):
    dt = jnp.dtype(cfg.dtype)
    k1, k2 = jax.random.split(key)
    return {"ln1": nn.layernorm_init(cfg.d_model, dt),
            "attn": _mha_init(k1, cfg),
            "ln2": nn.layernorm_init(cfg.d_model, dt),
            "mlp": _mlp_init(k2, cfg)}


def _dec_layer_init(key, cfg):
    dt = jnp.dtype(cfg.dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": nn.layernorm_init(cfg.d_model, dt),
            "self_attn": _mha_init(k1, cfg),
            "ln_x": nn.layernorm_init(cfg.d_model, dt),
            "cross_attn": _mha_init(k2, cfg),
            "ln2": nn.layernorm_init(cfg.d_model, dt),
            "mlp": _mlp_init(k3, cfg)}


def init(cfg: ModelConfig, key) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    enc_keys = jax.random.split(ks[0], cfg.encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.num_layers)
    return {
        "enc_layers": jax.vmap(lambda k: _enc_layer_init(k, cfg))(enc_keys),
        "enc_final_norm": nn.layernorm_init(cfg.d_model, dt),
        "embed": nn.embedding_init(ks[2], cfg.vocab_size, cfg.d_model, dt),
        "pos_embed": nn.normal(ks[3], (cfg.max_position_embeddings,
                                       cfg.d_model), 0.01, dt),
        "dec_layers": jax.vmap(lambda k: _dec_layer_init(k, cfg))(dec_keys),
        "final_norm": nn.layernorm_init(cfg.d_model, dt),
    }


def _heads(cfg, x):
    return x.reshape(*x.shape[:-1], cfg.num_heads, cfg.head_dim)


def _mha(p, cfg: ModelConfig, xq, xkv, *, causal: bool):
    q = _heads(cfg, nn.linear(p["wq"], xq))
    k = _heads(cfg, nn.linear(p["wk"], xkv))
    v = _heads(cfg, nn.linear(p["wv"], xkv))
    out = nn.flash_attention(q, k, v, causal=causal)
    return nn.linear(p["wo"], out.reshape(*xq.shape[:-1], cfg.q_dim))


def encode(params, cfg: ModelConfig, audio_embeds, *, train: bool = False):
    """audio_embeds: (B, F, d_model) stub frame embeddings."""
    x = audio_embeds + nn.sinusoid_positions(
        audio_embeds.shape[1], cfg.d_model).astype(audio_embeds.dtype)[None]
    x = constrain(x, "batch", "seq", "embed")

    def layer(p, cfg, x):
        x = x + _mha(p["attn"], cfg, nn.layernorm(p["ln1"], x),
                     nn.layernorm(p["ln1"], x), causal=False)
        h = nn.layernorm(p["ln2"], x)
        h = nn.linear(p["mlp"]["down"],
                      jax.nn.gelu(nn.linear(p["mlp"]["up"], h)))
        return x + h

    layer_fn = layer
    if cfg.remat and train:
        layer_fn = jax.checkpoint(layer, static_argnums=(1,),
                                  policy=jax.checkpoint_policies.nothing_saveable)

    def body(x, lp):
        return layer_fn(lp, cfg, x), None
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return nn.layernorm(params["enc_final_norm"], x)


def _dec_layer(p, cfg: ModelConfig, x, enc_out):
    x = x + _mha(p["self_attn"], cfg, nn.layernorm(p["ln1"], x),
                 nn.layernorm(p["ln1"], x), causal=True)
    x = x + _mha(p["cross_attn"], cfg, nn.layernorm(p["ln_x"], x), enc_out,
                 causal=False)
    h = nn.layernorm(p["ln2"], x)
    h = nn.linear(p["mlp"]["down"], jax.nn.gelu(nn.linear(p["mlp"]["up"], h)))
    return x + h


def forward(params, cfg: ModelConfig, batch, *, train: bool = False):
    """batch: {'tokens': (B, S), 'audio_embeds': (B, F, D)}."""
    enc_out = encode(params, cfg, batch["audio_embeds"], train=train)
    tokens = batch["tokens"]
    s = tokens.shape[1]
    pos = params["pos_embed"]
    if s > pos.shape[0]:  # assignment shapes exceed whisper's 448 positions
        pos = jnp.concatenate(
            [pos, nn.sinusoid_positions(s - pos.shape[0],
                                        cfg.d_model).astype(pos.dtype)])
    x = nn.embed(params["embed"], tokens) + pos[None, :s]
    x = constrain(x, "batch", "seq", "embed")

    layer_fn = _dec_layer
    if cfg.remat and train:
        layer_fn = jax.checkpoint(_dec_layer, static_argnums=(1,),
                                  policy=jax.checkpoint_policies.nothing_saveable)

    def body(x, lp):
        return layer_fn(lp, cfg, x, enc_out), None
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = nn.layernorm(params["final_norm"], x)
    logits = nn.unembed(params["embed"], x)
    return constrain(logits, "batch", "seq", "vocab"), {}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int,
               batch=None, params=None):
    """Self-attn KV cache + precomputed cross-attn K/V (from the encoder)."""
    dt = jnp.dtype(cfg.dtype)
    n = cfg.num_layers
    cache = {
        "k": jnp.zeros((n, batch_size, max_len, cfg.num_heads, cfg.head_dim),
                       dt),
        "v": jnp.zeros((n, batch_size, max_len, cfg.num_heads, cfg.head_dim),
                       dt),
        "pos": jnp.full((n, max_len), -1, jnp.int32),
        "cross_k": jnp.zeros((n, batch_size, cfg.encoder_seq, cfg.num_heads,
                              cfg.head_dim), dt),
        "cross_v": jnp.zeros((n, batch_size, cfg.encoder_seq, cfg.num_heads,
                              cfg.head_dim), dt),
    }
    if params is not None and batch is not None:
        enc_out = encode(params, cfg, batch["audio_embeds"])

        def xkv(lp):
            k = _heads(cfg, nn.linear(lp["cross_attn"]["wk"], enc_out))
            v = _heads(cfg, nn.linear(lp["cross_attn"]["wv"], enc_out))
            return k, v
        ck, cv = jax.vmap(xkv)(params["dec_layers"])
        cache["cross_k"], cache["cross_v"] = ck, cv
    return cache


def decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    x = nn.embed(params["embed"], tokens)
    pe = params["pos_embed"]
    pos_c = jnp.clip(pos, 0, pe.shape[0] - 1)
    x = x + jax.lax.dynamic_slice_in_dim(pe, pos_c, 1, 0)[None].reshape(
        1, 1, cfg.d_model)

    def body(x, inp):
        lp, c = inp
        h = nn.layernorm(lp["ln1"], x)
        q = _heads(cfg, nn.linear(lp["self_attn"]["wq"], h))
        k = _heads(cfg, nn.linear(lp["self_attn"]["wk"], h))
        v = _heads(cfg, nn.linear(lp["self_attn"]["wv"], h))
        k_cache = jax.lax.dynamic_update_slice_in_dim(c["k"], k, pos, 1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(c["v"], v, pos, 1)
        entry_pos = jax.lax.dynamic_update_slice_in_dim(
            c["pos"], jnp.full((1,), pos, jnp.int32), pos, 0)
        attn = nn.decode_attention(q, k_cache, v_cache, entry_pos=entry_pos,
                                   cur_pos=pos)
        x = x + nn.linear(lp["self_attn"]["wo"],
                          attn.reshape(x.shape[0], 1, cfg.q_dim))
        # cross attention against precomputed encoder K/V
        hx = nn.layernorm(lp["ln_x"], x)
        qx = _heads(cfg, nn.linear(lp["cross_attn"]["wq"], hx))
        f = cache["cross_k"].shape[2]
        attn = nn.decode_attention(
            qx, c["cross_k"], c["cross_v"],
            entry_pos=jnp.arange(f), cur_pos=jnp.asarray(f, jnp.int32))
        x = x + nn.linear(lp["cross_attn"]["wo"],
                          attn.reshape(x.shape[0], 1, cfg.q_dim))
        h2 = nn.layernorm(lp["ln2"], x)
        x = x + nn.linear(lp["mlp"]["down"],
                          jax.nn.gelu(nn.linear(lp["mlp"]["up"], h2)))
        return x, {"k": k_cache, "v": v_cache, "pos": entry_pos,
                   "cross_k": c["cross_k"], "cross_v": c["cross_v"]}

    x, new_cache = jax.lax.scan(body, x, (params["dec_layers"], cache))
    x = nn.layernorm(params["final_norm"], x)
    logits = nn.unembed(params["embed"], x)
    return logits, new_cache
