"""Shared pytest fixtures. NOTE: do NOT set xla_force_host_platform_device
count here — smoke tests and benches must see 1 device; only
launch/dryrun.py forces 512 placeholder devices (in its own process)."""
import os

import numpy as np
import pytest

os.environ.setdefault("JAX_PLATFORMS", "cpu")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
