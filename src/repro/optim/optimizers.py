"""Functional optimizers (mini-optax: init/update pairs over pytrees).

AdamW is the default; Adafactor (factored second moment) is used for the
trillion-parameter MoE where Adam's fp32 m/v would not fit HBM. Optimizer
state inherits the parameter sharding (ZeRO-style: FSDP-sharded params =>
FSDP-sharded m/v automatically under GSPMD).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Tuple[Any, Any]]  # (grads, state, params, step)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), \
        norm


def adamw(lr: Callable[[jax.Array], jax.Array] | float,
          b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, clip_norm: Optional[float] = 1.0
          ) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr))

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree_util.tree_map(zeros, params),
                "v": jax.tree_util.tree_map(zeros, params),
                "grad_norm": jnp.zeros((), jnp.float32)}

    def update(grads, state, params, step):
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = global_norm(grads)
        t = step.astype(jnp.float32) + 1.0
        lr_t = lr_fn(step)
        corr1 = 1.0 - b1 ** t
        corr2 = 1.0 - b2 ** t

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * jnp.square(g32)
            mh = m / corr1
            vh = v / corr2
            step_ = mh / (jnp.sqrt(vh) + eps) + \
                weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr_t * step_).astype(p.dtype)
            return new_p, m, v

        flat = jax.tree_util.tree_map(upd, grads, state["m"], state["v"],
                                      params)
        new_params = jax.tree_util.tree_map(lambda x: x[0], flat,
                                            is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree_util.tree_map(lambda x: x[1], flat,
                                       is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree_util.tree_map(lambda x: x[2], flat,
                                       is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v,
                            "grad_norm": gnorm}

    return Optimizer(init, update)


def adafactor(lr: Callable[[jax.Array], jax.Array] | float,
              decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0,
              weight_decay: float = 0.0) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern). Matrices store
    per-row + per-col accumulators (O(n+m) not O(nm)); vectors fall back
    to full accumulators."""
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr))

    def init_leaf(p):
        if p.ndim >= 2:
            return {"r": jnp.zeros(p.shape[:-1], jnp.float32),
                    "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    def init(params):
        return {"acc": jax.tree_util.tree_map(init_leaf, params),
                "grad_norm": jnp.zeros((), jnp.float32)}

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)
        lr_t = lr_fn(step)

        def upd(g, acc, p):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if p.ndim >= 2:
                r = beta * acc["r"] + (1 - beta) * g2.mean(axis=-1)
                c = beta * acc["c"] + (1 - beta) * g2.mean(axis=-2)
                rc = r / jnp.maximum(
                    r.mean(axis=-1, keepdims=True), eps)
                vhat = rc[..., None] * c[..., None, :]
                new_acc = {"r": r, "c": c}
            else:
                v = beta * acc["v"] + (1 - beta) * g2
                vhat = v
                new_acc = {"v": v}
            u = g32 * jax.lax.rsqrt(vhat + eps)
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), new_acc

        flat = jax.tree_util.tree_map(
            upd, grads, state["acc"], params,
            is_leaf=lambda x: isinstance(x, dict) and ("r" in x or "v" in x))
        new_params = jax.tree_util.tree_map(
            lambda x: x[0], flat, is_leaf=lambda x: isinstance(x, tuple))
        new_acc = jax.tree_util.tree_map(
            lambda x: x[1], flat, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"acc": new_acc,
                            "grad_norm": global_norm(grads)}

    return Optimizer(init, update)


def sgd(lr: Callable[[jax.Array], jax.Array] | float,
        momentum: float = 0.9) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr))

    def init(params):
        return {"mu": jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params),
                "grad_norm": jnp.zeros((), jnp.float32)}

    def update(grads, state, params, step):
        lr_t = lr_fn(step)

        def upd(g, mu, p):
            mu = momentum * mu + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * mu).astype(p.dtype), mu

        flat = jax.tree_util.tree_map(upd, grads, state["mu"], params)
        new_params = jax.tree_util.tree_map(
            lambda x: x[0], flat, is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree_util.tree_map(
            lambda x: x[1], flat, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"mu": new_mu, "grad_norm": global_norm(grads)}

    return Optimizer(init, update)
