"""Multi-lane decoder (Eq. 5) bit-exactness + bitmap/bitpack properties."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container has no hypothesis; use fixed-seed shim
    from _propcheck import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import bitpack, sparsity


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 6),
       st.lists(st.integers(0, 1), min_size=1, max_size=48))
def test_decode_cycle_extracts_first_m_bits(m, bits):
    """Lane m one-hot == position of the (m+1)-th set bit (Eq. 5)."""
    bits = np.array(bits)
    onehots, remaining = sparsity.multilane_decode_cycle(bits, m)
    expect = sparsity.naive_first_m_indices(bits, m)
    got = np.nonzero(onehots.any(axis=0))[0]
    np.testing.assert_array_equal(got, expect)
    # lanes fire in order, one position each
    for lane in range(min(m, len(expect))):
        assert np.nonzero(onehots[lane])[0].tolist() == [expect[lane]]
    # remaining = original minus extracted
    recon = remaining.copy()
    recon[expect] = True
    np.testing.assert_array_equal(recon, bits.astype(bool))


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 5),
       st.lists(st.integers(0, 1), min_size=1, max_size=40))
def test_decode_full_visits_every_bit_once_in_order(m, bits):
    bits = np.array(bits)
    cycles, n = sparsity.multilane_decode_full(bits, m)
    flat = np.concatenate(cycles) if cycles else np.array([])
    np.testing.assert_array_equal(np.sort(flat), np.nonzero(bits)[0])
    assert all(np.all(np.diff(c) > 0) for c in cycles)
    pc = int(bits.sum())
    assert n == sparsity.decode_cycles_for_word(pc, m)


def test_paper_fig6_example():
    """0x9042 takes 4 cycles single-lane, 1 cycle with M=4 (Fig. 6A)."""
    bits = np.array([(0x9042 >> i) & 1 for i in range(16)])
    _, n1 = sparsity.multilane_decode_full(bits, 1)
    _, n4 = sparsity.multilane_decode_full(bits, 4)
    assert n1 == 4 and n4 == 1


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 4), st.integers(1, 4))
def test_bitmap_roundtrip(rows, words):
    rng = np.random.default_rng(rows * 7 + words)
    spikes = (rng.random((rows, words * 32)) > 0.75).astype(np.float32)
    enc, pc = sparsity.bitmap_encode(spikes)
    dec = sparsity.bitmap_decode(enc, words * 32)
    np.testing.assert_array_equal(dec, spikes)
    np.testing.assert_array_equal(pc.sum(axis=-1), spikes.sum(axis=-1))


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 3), st.integers(1, 3))
def test_jax_bitpack_roundtrip(rows, words):
    rng = np.random.default_rng(rows + 13 * words)
    x = (rng.random((rows, words * 32)) > 0.5).astype(np.float32)
    packed = bitpack.pack_bits(jnp.asarray(x))
    out = bitpack.unpack_bits(packed, words * 32)
    np.testing.assert_array_equal(np.asarray(out), x)


def test_popcount_matmul_equals_binary_dot():
    rng = np.random.default_rng(3)
    a = (rng.random((9, 96)) > 0.7).astype(np.float32)
    b = (rng.random((11, 96)) > 0.7).astype(np.float32)
    got = bitpack.popcount_matmul(bitpack.pack_bits(jnp.asarray(a)),
                                  bitpack.pack_bits(jnp.asarray(b)))
    np.testing.assert_array_equal(np.asarray(got),
                                  (a @ b.T).astype(np.int32))


def test_block_occupancy():
    s = np.zeros((4, 64))
    s[1, 40] = 1
    occ = sparsity.block_occupancy(s, 32)
    assert occ.shape == (4, 2)
    assert occ[1].tolist() == [False, True]
    assert not occ[0].any()
