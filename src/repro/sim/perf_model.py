"""End-to-end throughput / energy model (Table IV reproduction).

Computes FireFly-T's effective GOP/s, GOP/s/W and GOP/s/DSP for CIFAR-Net,
Spikingformer-4-256 and Spikingformer-8-512 from:

  * per-layer workloads enumerated from the network definitions,
  * the sparse-engine cycle model (words x E[max(1, ceil(pc/G))] with the
    binomial spike model at the layer's sparsity),
  * the dual-engine latency-hiding schedule (attention cycles overlap the
    Q/K/V projections; residual non-hidden cycles are charged),
  * a power model calibrated on the paper's two implied operating points
    (G=2: 3.71 W, G=4: 4.35 W) using the 1 DSP ~ 86 LUT equivalence [40].

Baselines (FireFly v2, SpikeTA, DeepFire2, ...) enter as their published
Table IV numbers; the reproduced ratios are the paper's headline claims:
1.39x / 2.40x energy efficiency and 4.21x / 7.10x DSP efficiency.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from .resource_model import HardwareConfig, resource_breakdown

# ---------------------------------------------------------------------------
# layer workload enumeration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LayerSpec:
    name: str
    macs: float                 # dense-equivalent MACs (per timestep)
    words: float                # P_Ci-bit input words to decode (per ts)
    sparsity: float             # spike sparsity of the layer's input
    is_attention: bool = False  # binary-engine op (QK^T / QK^TV)


def conv_layer(name, fh, fw, cin, cout, k, sparsity, p_ci):
    macs = fh * fw * cin * cout * k * k
    words = fh * fw * k * k * max(1, cin // p_ci)
    return LayerSpec(name, macs, words, sparsity)


def linear_layer(name, l, cin, cout, sparsity, p_ci):
    return LayerSpec(name, l * cin * cout, l * max(1, cin // p_ci), sparsity)


def attn_layer(name, l, d, sparsity):
    # QK^T + QK^TV per head-group handled by the binary engine
    return LayerSpec(name, 2 * l * l * d, 0, sparsity, is_attention=True)


def cifarnet_layers(p_ci: int) -> List[LayerSpec]:
    """3x32x32-32c3-256c3-256c3-mp2-256c3-256c3-256c3-mp2-512c3-mp2-1024c3."""
    spec = [(32, 32, 3, 32, 0.70), (32, 32, 32, 256, 0.86),
            (32, 32, 256, 256, 0.90), (16, 16, 256, 256, 0.88),
            (16, 16, 256, 256, 0.92), (16, 16, 256, 256, 0.92),
            (8, 8, 256, 512, 0.93), (4, 4, 512, 1024, 0.94)]
    return [conv_layer(f"conv{i}", fh, fw, ci, co, 3, s, p_ci)
            for i, (fh, fw, ci, co, s) in enumerate(spec)]


def spikingformer_layers(blocks: int, d: int, l: int, img: int,
                         p_ci: int) -> List[LayerSpec]:
    """SPS stem + encoder blocks (QKV/proj/MLP linears + attention)."""
    layers: List[LayerSpec] = []
    # SPS ladder (channels d/8 -> d, pools towards l tokens)
    chans = [3, d // 8, d // 4, d // 2, d]
    res = img
    for i in range(4):
        layers.append(conv_layer(f"sps{i}", res, res, chans[i], chans[i + 1],
                                 3, 0.80 if i else 0.50, p_ci))
        if (img == 32 and i >= 2) or (img == 224):
            res //= 2
    for b in range(blocks):
        s = 0.78 + 0.08 * (b / max(1, blocks - 1))   # Fig. 11-like profile
        for nm in ("q", "k", "v"):
            layers.append(linear_layer(f"blk{b}.{nm}", l, d, d, s, p_ci))
        layers.append(attn_layer(f"blk{b}.attn", l, d, 0.9))
        layers.append(linear_layer(f"blk{b}.proj", l, d, d, 0.88, p_ci))
        layers.append(linear_layer(f"blk{b}.mlp1", l, d, 4 * d, s, p_ci))
        layers.append(linear_layer(f"blk{b}.mlp2", l, 4 * d, d, 0.85, p_ci))
    return layers


NETWORKS: Dict[str, Dict] = {
    "cifarnet": dict(layers=lambda hw: cifarnet_layers(hw.p_ci),
                     time_steps=4, img=32,
                     input_macs_per_frame=None),
    "spikingformer-4-256": dict(
        layers=lambda hw: spikingformer_layers(4, 256, 64, 32, hw.p_ci),
        time_steps=4, img=32),
    "spikingformer-8-512": dict(
        layers=lambda hw: spikingformer_layers(8, 512, 196, 224, hw.p_ci),
        time_steps=4, img=224),
}


# ---------------------------------------------------------------------------
# cycle model
# ---------------------------------------------------------------------------


def _binom_pmf(p_ci: int, q: float) -> np.ndarray:
    ks = np.arange(p_ci + 1)
    logc = (np.vectorize(math.lgamma)(p_ci + 1) -
            np.vectorize(math.lgamma)(ks + 1) -
            np.vectorize(math.lgamma)(p_ci - ks + 1))
    with np.errstate(divide="ignore"):
        logp = logc + ks * np.log(max(q, 1e-12)) + \
            (p_ci - ks) * np.log(max(1 - q, 1e-12))
    return np.exp(logp)


def word_cycles(p_ci: int, g: int, sparsity: float,
                straggler_frac: float = 0.05) -> float:
    pmf = _binom_pmf(p_ci, 1.0 - sparsity)
    ks = np.arange(p_ci + 1)
    cyc = np.maximum(1, np.ceil(ks / g))
    return float((pmf * cyc).sum() * (1.0 + straggler_frac))


@dataclass
class PerfResult:
    network: str
    total_gops_per_frame: float     # dense-equivalent GOP per inference
    cycles_per_frame: float
    gops: float                     # effective GOP/s
    fps: float
    power_w: float
    energy_eff: float               # GOP/s/W
    dsps: int
    dsp_eff: float                  # GOP/s/DSP
    hidden_attention_frac: float    # fraction of attention cycles hidden


def power_model(hw: HardwareConfig,
                include_binary: bool = True) -> Tuple[float, float, int]:
    """Calibrated: P = 3.0 + 0.027 * (kLUT + 0.086 * 0.33 * DSP) W.

    Returns (power_w, kluts, dsps). Networks without attention (CIFAR-Net)
    exclude the binary engine (the overlay gates it off)."""
    br = resource_breakdown(hw)
    if not include_binary:
        br = {k: v for k, v in br.items() if k != "binary_engine"}
    kluts = sum(v["kluts"] for v in br.values())
    dsps = int(sum(v["dsps"] for v in br.values()))
    p = 3.0 + 0.027 * (kluts + 0.086 * 0.33 * dsps)
    return p, kluts, dsps


# per-family pipeline/DMA overhead (calibrated on Table IV FPS anchors)
_OVERHEAD = {"conv": 0.04, "transformer": 0.22}


def evaluate(network: str, hw: Optional[HardwareConfig] = None) -> PerfResult:
    hw = hw or HardwareConfig()
    net = NETWORKS[network]
    layers = net["layers"](hw)
    ts = net["time_steps"]

    total_macs = 0.0
    sparse_cycles = 0.0
    attn_cycles_raw = 0.0
    proj_cycles_for_overlap = 0.0
    p_b = hw.p_bm * hw.p_bn * hw.p_bk
    for layer in layers:
        total_macs += ts * layer.macs
        if layer.is_attention:
            attn_cycles_raw += ts * layer.macs / p_b
        else:
            co_tiles = max(1.0, layer.macs / layer.words / hw.p_ci / hw.p_co) \
                if layer.words else 1.0
            wc = word_cycles(hw.p_ci, hw.g, layer.sparsity)
            cyc = ts * layer.words * wc * co_tiles / hw.p_tsfx
            sparse_cycles += cyc
            if ".q" in layer.name or ".k" in layer.name or \
                    ".v" in layer.name:
                proj_cycles_for_overlap += cyc

    # latency hiding: attention overlaps the Q/K/V projections
    hidden = min(attn_cycles_raw, proj_cycles_for_overlap)
    visible_attn = attn_cycles_raw - hidden
    has_attn = attn_cycles_raw > 0
    overhead = _OVERHEAD["transformer" if has_attn else "conv"]
    total_cycles = (sparse_cycles + visible_attn) * (1.0 + overhead)
    hidden_frac = (hidden / attn_cycles_raw) if attn_cycles_raw else 1.0

    t_frame = total_cycles / (hw.freq_mhz * 1e6)
    gop_frame = 2.0 * total_macs / 1e9
    gops = gop_frame / t_frame
    power, _, dsps = power_model(hw, include_binary=has_attn)
    return PerfResult(network, gop_frame, total_cycles, gops, 1.0 / t_frame,
                      power, gops / power, dsps, gops / dsps, hidden_frac)


# published Table IV baselines (GOP/s/W, GOP/s/DSP)
PUBLISHED = {
    "firefly_v2_cifar": dict(energy_eff=702.74, dsp_eff=6.73),
    "firefly_v2_imagenet": dict(energy_eff=633.33, dsp_eff=6.06),
    "spiketa_imagenet": dict(energy_eff=403.99, dsp_eff=4.04),
    "spiketa_cifar": dict(energy_eff=408.57, dsp_eff=3.99),
    "deepfire2_imagenet": dict(energy_eff=447.00, dsp_eff=3.90),
    "heatvit": dict(energy_eff=46.82, dsp_eff=0.22),
    "ssr": dict(energy_eff=246.15, dsp_eff=6.06),
    # paper-reported FireFly-T rows (for model-vs-paper deltas)
    "fireflyt_cifarnet": dict(gops=3630, energy_eff=978.61, dsp_eff=28.35),
    "fireflyt_sf4_256": dict(gops=3029, energy_eff=696.64, dsp_eff=9.96),
    "fireflyt_sf8_512": dict(gops=3397, energy_eff=781.13, dsp_eff=11.11),
}


def headline_ratios() -> Dict[str, float]:
    """The abstract's claims, from OUR model vs published baselines.

    The paper's 1.39x/2.40x (energy) and 4.21x/7.10x (DSP) compare
    FireFly-T's best row (CIFAR-Net, G=2) against FireFly v2's and
    SpikeTA's best rows respectively (978.61/702.74 = 1.39,
    978.61/408.57 = 2.40, 28.35/6.73 = 4.21, 28.35/3.99 = 7.10)."""
    cifar = evaluate("cifarnet", HardwareConfig(g=2))
    return {
        "energy_vs_fireflyv2": cifar.energy_eff /
        PUBLISHED["firefly_v2_cifar"]["energy_eff"],
        "energy_vs_spiketa": cifar.energy_eff /
        PUBLISHED["spiketa_cifar"]["energy_eff"],
        "dsp_vs_fireflyv2": cifar.dsp_eff /
        PUBLISHED["firefly_v2_cifar"]["dsp_eff"],
        "dsp_vs_spiketa": cifar.dsp_eff /
        PUBLISHED["spiketa_cifar"]["dsp_eff"],
    }
