"""Bit-packed AND-PopCount attention scores — the faithful FPGA-port
variant of the binary engine (for comparison against the MXU kernel).

FireFly-T computes QK^T with LUT6 compressor trees over 1-bit operands.
The literal TPU port packs spikes into uint32 lanes and uses the VPU's
``population_count`` on ``q & k``. This keeps the 32x storage compression
but trades the MXU's 128x128 systolic throughput for VPU element ops —
benchmarks show the MXU variant dominates on TPU (DESIGN.md §3, the
documented hardware-adaptation result). Kept as a first-class kernel to
(a) pin the bit-exact AND-PopCount semantics and (b) quantify the gap.

Layout: q_packed (BH, Lq, W) uint32, k_packed (BH, Lk, W) uint32;
grid (BH, nQ, nK); output int32 overlap counts (BH, Lq, Lk).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.bitpack import pad_to_multiple


def _kernel(q_ref, k_ref, o_ref):
    q = q_ref[0]                                   # (bq, W) uint32
    k = k_ref[0]                                   # (bk, W) uint32
    anded = q[:, None, :] & k[None, :, :]          # (bq, bk, W)
    o_ref[0] = jax.lax.population_count(anded).sum(
        axis=-1).astype(jnp.int32)


def popcount_scores(q_packed: jax.Array, k_packed: jax.Array, *,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None) -> jax.Array:
    """(BH, Lq, W) x (BH, Lk, W) uint32 -> (BH, Lq, Lk) int32 counts.

    Lq / Lk that don't divide the blocks are zero-padded (all-zero words
    popcount to 0) and the count matrix is sliced back — serve prompts
    are rarely block-multiples.
    """
    bh, lq, w = q_packed.shape
    _, lk, _ = k_packed.shape
    block_q = min(block_q, lq)
    block_k = min(block_k, lk)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    qp = pad_to_multiple(q_packed, 1, block_q)
    kp = pad_to_multiple(k_packed, 1, block_k)
    lqp, lkp = qp.shape[1], kp.shape[1]

    grid = (bh, lqp // block_q, lkp // block_k)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, w), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, w), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, block_k),
                               lambda b, qi, ki: (b, qi, ki)),
        out_shape=jax.ShapeDtypeStruct((bh, lqp, lkp), jnp.int32),
        interpret=interpret,
    )(qp, kp)
    return out[:, :lq, :lk]
