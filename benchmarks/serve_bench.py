"""Serving orchestrator sweep: slots x prefill-chunk x mesh throughput.

Runs the continuous-batching server (launch/serve.py) over a synthetic
request stream for every (arch, slots, chunk, mesh) cell on a forced
8-device host platform and emits artifacts/serve_bench.json.

Usage:
  PYTHONPATH=src python benchmarks/serve_bench.py [--smoke] [--out PATH]

CPU caveat (recorded in derived): wall-clock here measures the XLA CPU
backend (and interpret-mode kernels for the spiking arch); the sweep's
value is the *relative* shape — chunked prefill vs token-at-a-time, mesh
scaling overhead vs slot parallelism — not absolute tok/s.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8" + \
    (" " + os.environ.get("XLA_FLAGS_EXTRA", "") if
     os.environ.get("XLA_FLAGS_EXTRA") else "")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse   # noqa: E402
import json       # noqa: E402
import sys        # noqa: E402
import time       # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax        # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config            # noqa: E402
from repro.launch.mesh import make_serve_mesh   # noqa: E402
from repro.launch.serve import BatchedServer, Request  # noqa: E402
from repro.models import registry               # noqa: E402

ARCHS = ("h2o-danube-3-4b", "spikingformer-lm")
MESHES = (None, (2, 1), (2, 2), (4, 2))         # (data, model) or unsharded


def run_cell(cfg, params, *, slots, chunk, mesh_shape, requests=8,
             prompt_len=12, max_new=8, max_len=48):
    mesh = None if mesh_shape is None else make_serve_mesh(*mesh_shape)
    server = BatchedServer(cfg, params, slots, max_len, chunk=chunk,
                           mesh=mesh)
    rng = np.random.default_rng(0)
    for i in range(requests):
        server.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                       prompt_len).astype(np.int32),
            max_new_tokens=max_new))
    t0 = time.time()
    waves = server.run()
    dt = time.time() - t0
    n_gen = sum(len(r.generated) for r in server.completed)
    n_pre = sum(len(r.prompt) for r in server.completed)
    return {"arch": cfg.name, "slots": slots,
            "chunk": "auto" if chunk == 0 else chunk,
            "mesh": "none" if mesh_shape is None else
            f"{mesh_shape[0]}x{mesh_shape[1]}",
            "requests": requests, "prompt_tokens": n_pre,
            "gen_tokens": n_gen, "waves": waves,
            "wall_s": round(dt, 3),
            "tok_s": round((n_pre + n_gen) / dt, 1)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweep for CI")
    ap.add_argument("--out", default="artifacts/serve_bench.json")
    args = ap.parse_args()

    slots_sweep = (2, 4) if args.smoke else (2, 4, 8)
    chunk_sweep = (1, 0) if args.smoke else (1, 4, 0)     # 0 = policy
    meshes = (None, (2, 2)) if args.smoke else MESHES

    rows = []
    for arch in ARCHS:
        cfg = get_config(arch, smoke=True)
        params = registry.init(cfg, jax.random.PRNGKey(0))
        for slots in slots_sweep:
            for chunk in chunk_sweep:
                for mesh_shape in meshes:
                    row = run_cell(cfg, params, slots=slots, chunk=chunk,
                                   mesh_shape=mesh_shape)
                    rows.append(row)
                    print(f"[serve_bench] {row['arch']} slots={slots} "
                          f"chunk={row['chunk']} mesh={row['mesh']}: "
                          f"{row['tok_s']} tok/s ({row['waves']} waves)")

    def best(rs):
        return max(rs, key=lambda r: r["tok_s"])

    derived = {
        "measurement": "XLA CPU backend, forced 8-device host platform; "
                       "kernels in interpret mode — relative shape only",
        "devices": len(jax.devices()),
        "best_cell_per_arch": {a: best([r for r in rows if r["arch"] == a])
                               for a in ARCHS},
        # chunked prefill drains the same stream in fewer waves; wave
        # reduction is backend-independent (it is scheduler geometry).
        # Compared at the largest slot count, unsharded, vs chunk=1.
        "wave_reduction_chunked_vs_1": {},
    }
    top = max(slots_sweep)
    for a in ARCHS:
        cells = [r for r in rows if r["arch"] == a and r["slots"] == top
                 and r["mesh"] == "none"]
        base = next(r["waves"] for r in cells if r["chunk"] == 1)
        chunked = min((r["waves"] for r in cells if r["chunk"] != 1),
                      default=base)
        derived["wave_reduction_chunked_vs_1"][a] = round(chunked / base, 3)
    out = {"rows": rows, "derived": derived}
    if os.path.dirname(args.out):
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"[serve_bench] {len(rows)} cells -> {args.out}")


if __name__ == "__main__":
    main()
