"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144 — 5:1 local:global sliding window, qk-norm, GeGLU
[hf:google/gemma-3 family]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b", family="dense",
    num_layers=48, d_model=3840, num_heads=16, num_kv_heads=8, head_dim=256,
    d_ff=15360, vocab_size=262144,
    attn_type="local_global", global_every=6, window=1024,
    qk_norm=True, act="gelu", gated=True, rope_theta=1_000_000.0,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    num_layers=4, global_every=2, window=8, d_model=96, num_heads=4,
    num_kv_heads=2, head_dim=24, d_ff=192, vocab_size=512,
    dtype="float32", remat=False)
