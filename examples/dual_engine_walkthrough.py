"""Walkthrough of FireFly-T's mechanisms, end to end:

1. the multi-lane sparse decoder on the paper's own Fig. 6 example;
2. load balancing: unified wide bank vs crossbar;
3. the latency-hiding pipeline (Eq. 3/4) sized for Spikingformer-8-512;
4. the TPU kernels computing the same binary attention two ways
   (MXU dot vs bit-packed AND-popcount) — bit-identical results.

    PYTHONPATH=src python examples/dual_engine_walkthrough.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dual_engine import (AttentionWorkload, EngineParallelism,
                                    pipeline_schedule,
                                    required_binary_parallelism)
from repro.core.sparsity import multilane_decode_full
from repro.kernels import ops
from repro.sim import balance_sim as bs


def main():
    print("== 1. multi-lane sparse decoder (paper Fig. 6A) ==")
    bits = np.array([(0x9042 >> i) & 1 for i in range(16)])
    for m in (1, 4):
        cycles, n = multilane_decode_full(bits, m)
        print(f"  bitmap 0x9042, M={m}: {n} cycle(s); "
              f"indices per cycle: {[c.tolist() for c in cycles]}")

    print("\n== 2. load balancing: unified wide bank vs crossbar ==")
    res = bs.compare(n_pes=16, n_banks=4, throughput=4)
    print(f"  16 PEs, 4 banks, G=4, 75% sparsity: crossbar "
          f"{res.crossbar_cycles} cyc vs ours {res.unified_cycles} cyc "
          f"({res.speedup:.2f}x)")

    print("\n== 3. latency-hiding pipeline (Eq. 3/4) ==")
    w = AttentionWorkload(T_s=4, F_h=14, F_w=14, C_i=512, P_Co=64, heads=8)
    p = EngineParallelism(P_Ts=2, P_Fx=4, P_Ci=16, P_Co=64,
                          P_Bm=8, P_Bn=8, P_Bk=32)
    print(f"  Eq.4 required P_b ~= {required_binary_parallelism(w, p):.0f}, "
          f"chosen P_b = {p.P_b}")
    se, be, overlapped, serial = pipeline_schedule(w, p)
    print(f"  serial {serial} cyc -> overlapped {overlapped} cyc "
          f"({serial/overlapped:.2f}x hiding gain)")
    for name, s, e in se[:4]:
        print(f"    sparse  {name:4s} [{s:9.0f}, {e:9.0f})")
    for name, s, e in be[:2]:
        print(f"    binary  {name:8s} [{s:9.0f}, {e:9.0f})")

    print("\n== 4. binary attention: MXU dot vs AND-popcount (bit-exact) ==")
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 2)
    q = (jax.random.uniform(ks[0], (2, 64, 64)) > 0.75).astype(jnp.float32)
    k = (jax.random.uniform(ks[1], (2, 64, 64)) > 0.75).astype(jnp.float32)
    mxu_scores = jnp.einsum("bld,bmd->blm", q, k).astype(jnp.int32)
    pop_scores = ops.popcount_attention_scores(q, k)
    print(f"  MXU == popcount: "
          f"{bool(jnp.array_equal(mxu_scores, pop_scores))} "
          f"(max overlap count {int(pop_scores.max())})")
    out = ops.spike_attention(q.reshape(2, 64, 1, 64),
                              k.reshape(2, 64, 1, 64),
                              k.reshape(2, 64, 1, 64),
                              scale=1 / 8.0, delta=0.3, causal=False)
    print(f"  fused spike_attention output shape {out.shape}, "
          f"mean {float(out.mean()):.3f}")

    print("\n== 5. dual-engine dispatch: dense vs occupancy-skipping ==")
    from repro.core import engine as E
    from repro.kernels.spike_matmul import block_occupancy
    ks = jax.random.split(jax.random.PRNGKey(7), 2)
    # coherent channel sparsity (Observation 1): half the channel blocks
    # are dark, so whole (32 x 32) tiles drop out of the matmul.
    s = (jax.random.uniform(ks[0], (4, 2, 64, 128)) < 0.25).astype(
        jnp.float32)
    s = s * (jax.random.uniform(ks[1], (1, 1, 1, 128 // 32)) < 0.5
             ).astype(jnp.float32).repeat(32, -1)
    w = jax.random.randint(jax.random.PRNGKey(8), (128, 64), -128,
                           128).astype(jnp.float32) * 2.0 ** -8
    p_lin = {"w": w}
    dense = E.spike_linear(p_lin, s, engine=E.DENSE)
    sparse = E.spike_linear(p_lin, s, engine=E.EngineConfig(
        mode="sparse", block_m=32, block_n=32, block_k=32))
    occ = block_occupancy(s.reshape(-1, 128), 32, 32)
    print(f"  (T,B,L,K)=(4,2,64,128) spike_linear: dense == sparse "
          f"bitwise: {bool((dense == sparse).all())}")
    print(f"  tile skip fraction {float(1 - occ.mean()):.2f} -> "
          f"{1.0 / max(1e-9, float(occ.mean())):.2f}x MAC reduction")


if __name__ == "__main__":
    main()
