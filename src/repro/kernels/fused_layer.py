"""Fused whole-layer step — the layer-program the dual-engine overlay runs.

PR 6 (``kernels/fused_ssa.py``) fused the SSA *bundle* (Q/K/V
projections + binary attention) onto one Pallas grid; the MLP of layer
l still ran sequentially after attention, the fused projections only
skipped at spike-*slab* granularity, and the binary phases never
skipped at all. This kernel extends the fusion to the **entire encoder
layer** — the paper's orchestrator overlaps the sparse and binary
engines across the whole layer dataflow, not just the bundle:

Grid ``(B, P, H)`` (``overlap='fused'``) or ``(B, T, P, H)``
(``overlap='pipeline'`` — the timestep/layer axis from ROADMAP made a
grid axis: every phase advances one timestep at a time, LIF membranes
ride VMEM scratch across the T axis, and on a pipelined backend layer
l+1's projection phases stream in behind layer l's MLP tiles on the
same wavefront), with P = 8 per-head phases (:data:`LAYER_PHASES`):

  sparse engine: ``q / k / v`` projections (+ BN/RoPE epilogue + LIF),
                 ``wo`` head-slice, ``up`` / ``down`` MLP ff-chunks
  binary engine: ``qkt`` (scores + binarize + mask), ``qktv`` (context)

Three sparsity mechanisms, all *measured* (only executed sub-blocks
reach the counts output) and all exact (skipped work contributes +0):

* decoded gather (``sparse='decoded'``): each spike slab's live
  entries are prefix-compacted on-device
  (:func:`repro.kernels.spike_decode.slab_decode`, built on the PR 5
  ``decode_indices``) and the projection phases contract only
  ``w[idx]`` gathers, chunk-skipped under per-L-block pow2
  occupancy-bucket caps — the fine-grained decoded datapath, now
  reachable from inside the fused step. Restricted to the spike-driven
  family (vision): splitting the K contraction into gather chunks is
  only order-free in fp32 — hence bitwise — when every partial sum is
  exact ({0,1} spikes x dyadic / integer-code weights, DESIGN.md §4);
  the token family's projections consume *analog* normed currents, so
  there ``decoded`` degenerates to the tile skip (same dispatch
  outcome, still bitwise). ``sparse='tile'`` keeps block-granular
  occupancy skips (the PR 6 slab skip, refined to L-block resolution).
* occupancy map for the binary phases: per (head, key/value-axis
  L-block) the ``qkt`` phase skips all-dark key blocks (their scores
  are exact zeros, which binarize to zero whenever delta > 0 — when
  delta <= 0 the predicate forces execution) and the ``qktv`` phase
  skips blocks whose binarized scores or value spikes are all dark —
  the byte-level-write analogy of the paper's binary engine
  (DESIGN.md §11).
* ``wo`` / ``up`` / ``down`` skip all-dark input row blocks
  (bias-free linears: a zero row block contributes exact fp32 zeros).

The counts output is a ``(H, 8, n_l_blocks)`` int32 occupancy map —
the PR 6 ``(H, 4)`` executed-step counts extended per phase and per
L-block — consumed by ``core.dual_engine.fused_step_metrics`` for the
per-phase measured hidden fraction.

Bit-exactness (DESIGN.md §4 contract): every contraction accumulates
fp32 over exact or un-split operands, epilogues repeat the reference
expressions (``nn.batchnorm`` eval affine, ``nn.rope``, ``nn.rmsnorm``,
``core.spiking.lif_step``) on identical dtypes, and the fused / pipeline
grids execute identical math — so :func:`reference_layer` below (the
sequential layer composition ``models/spikingformer._block`` /
``models/transformer.apply_layer`` used to inline) is matched bitwise
on the layer output, and is the recompute target of the fused path's
custom VJP (``core.engine``). Like PR 5/6, validated in interpret mode
(the container's execution mode); ``overlap='auto'`` never volunteers
the fused layer on a real TPU backend.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.spiking import SpikingConfig, lif_scan

FAMILIES = ("bn", "rope")
# per-head phases of the layer program: three sparse projections, the
# two binary-engine phases, then the post-attention sparse phases
LAYER_PHASES = ("q", "k", "v", "qkt", "qktv", "wo", "up", "down")
N_PHASES = len(LAYER_PHASES)


def _kernel(*refs, family, decoded, pipeline, t_steps, l, k_dim, d_model,
            head_dim, num_heads, ffc, l_block, c_block, nc, nlb, scale,
            causal, binarize_scores, decay, v_th, soft_reset, eps,
            norm_eps, dtype):
    if decoded:
        (x_ref, s_ref, w3_ref, wo_ref, w1_ref, w2_ref, sc3_ref, sco_ref,
         sc1_ref, sc2_ref, auxp_ref, auxo_ref, aux1_ref, aux2_ref,
         delta_ref, idx_ref, val_ref, cap_ref, o_ref, cnt_ref,
         sq, sk, sv, scr, ctxs, hids, attn_acc, dn_acc, x1s, s2s,
         uq, uk, uv, us2, uh) = refs
    else:
        (x_ref, s_ref, w3_ref, wo_ref, w1_ref, w2_ref, sc3_ref, sco_ref,
         sc1_ref, sc2_ref, auxp_ref, auxo_ref, aux1_ref, aux2_ref,
         delta_ref, o_ref, cnt_ref,
         sq, sk, sv, scr, ctxs, hids, attn_acc, dn_acc, x1s, s2s,
         uq, uk, uv, us2, uh) = refs
    if pipeline:
        b, ti = pl.program_id(0), pl.program_id(1)
        p, h = pl.program_id(2), pl.program_id(3)
        trange = (ti,)
        first_step = (b == 0) & (ti == 0) & (p == 0) & (h == 0)
    else:
        b = pl.program_id(0)
        p, h = pl.program_id(1), pl.program_id(2)
        trange = tuple(range(t_steps))
        first_step = (b == 0) & (p == 0) & (h == 0)
    half = head_dim // 2
    blocks = [(lb, lb * l_block, min(l, (lb + 1) * l_block))
              for lb in range(nlb)]
    slot = lambda t: h * t_steps + t          # flattened (head, t) scratch

    def _patch(buf, r0, r1, val, *, axis=0, add=False):
        # .at[] with a static slice covering the whole axis lowers to a
        # scatter whose empty int32 index array pallas rejects as a
        # captured constant; full coverage needs no slicing at all
        if r0 == 0 and r1 == buf.shape[axis]:
            return buf + val if add else val
        if axis == 0:
            return (buf.at[r0:r1].add(val) if add
                    else buf.at[r0:r1].set(val))
        return (buf.at[:, r0:r1].add(val) if add
                else buf.at[:, r0:r1].set(val))

    @pl.when(first_step)
    def _init_counts():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    def _bump(col, nexec):
        # occupancy map: executed sub-blocks for phase `col`, per L-block
        vec = jnp.stack([n.astype(jnp.int32) for n in nexec])
        ij = (h, jnp.int32(col), slice(None))
        pl.store(cnt_ref, ij, pl.load(cnt_ref, ij) + vec)

    def _lif(u_ref, uslot, t, y_t):
        # one lif_step; the membrane rides scratch so the pipeline grid
        # carries it across the T axis (the fused grid round-trips it
        # within one invocation — identical values either way)
        if pipeline:
            u = jnp.where(t == 0, jnp.zeros_like(y_t), u_ref[uslot])
        else:
            u = jnp.zeros_like(y_t) if t == 0 else u_ref[uslot]
        u = decay * u + y_t
        s_t = (u - v_th >= 0).astype(dtype)
        u = u - s_t * v_th if soft_reset else u * (1.0 - s_t)
        u_ref[uslot] = u
        return s_t

    def project(dst, u_ref, col, roped):
        # sparse-engine projection phase: per (timestep, L-block) either
        # the decoded w[idx] gather chunks under the bucket caps or the
        # tile path's occupancy-skipped dense dot, then the projection
        # epilogue (quant scale, BN affine / RoPE) and LIF — per head.
        w = w3_ref[0]                                    # (K, hd)
        nexec = [jnp.int32(0)] * nlb
        for t in trange:
            if decoded:
                idx_t = idx_ref[0][t]                    # (L, Cp) int32
                val_t = val_ref[0][t]                    # (L, Cp) fp32
                cap_t = cap_ref[0][t]                    # (nlb,) int32
            else:
                slab = s_ref[0][t]                       # (L, K)
            cur = jnp.zeros((l, head_dim), jnp.float32)
            for lb, r0, r1 in blocks:
                if decoded:
                    acc = jnp.zeros((r1 - r0, head_dim), jnp.float32)
                    for ci in range(nc):
                        live = ci * c_block < cap_t[lb]
                        iblk = idx_t[r0:r1,
                                     ci * c_block:(ci + 1) * c_block]
                        vblk = val_t[r0:r1,
                                     ci * c_block:(ci + 1) * c_block]
                        acc = jax.lax.cond(
                            live,
                            lambda a=acc, i=iblk, v=vblk: a +
                            jax.lax.dot_general(
                                v[:, None, :],
                                w[i].astype(jnp.float32),
                                (((2,), (1,)), ((0,), (0,))),
                                preferred_element_type=jnp.float32)[:, 0],
                            lambda a=acc: a)
                        nexec[lb] += live.astype(jnp.int32)
                else:
                    rows = slab[r0:r1]
                    occ = jnp.any(rows != 0)
                    acc = jax.lax.cond(
                        occ,
                        lambda r=rows: jax.lax.dot_general(
                            r, w, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32),
                        lambda: jnp.zeros((r1 - r0, head_dim),
                                          jnp.float32))
                    nexec[lb] += occ.astype(jnp.int32)
                cur = _patch(cur, r0, r1, acc)
            cur = cur * sc3_ref[0].astype(jnp.float32)   # quant epilogue
            y_t = cur.astype(dtype)                      # act dtype, like
            if family == "bn":                           # the dense ref
                mean, var = auxp_ref[0, 0], auxp_ref[0, 1]
                sc, bi = auxp_ref[0, 2], auxp_ref[0, 3]
                y32 = y_t.astype(jnp.float32)
                y32 = (y32 - mean) * jax.lax.rsqrt(var + eps)
                y_t = (y32 * sc + bi).astype(dtype)      # nn.batchnorm eval
            elif roped:                                  # rope: q, k only
                cos, sin = auxp_ref[0], auxp_ref[1]      # (L, half)
                x1 = y_t[..., :half].astype(jnp.float32)
                x2 = y_t[..., half:].astype(jnp.float32)
                y_t = jnp.concatenate([x1 * cos - x2 * sin,
                                       x2 * cos + x1 * sin],
                                      -1).astype(dtype)
            dst[slot(t)] = _lif(u_ref, h, t, y_t)
        _bump(col, nexec)

    @pl.when(p == 0)
    def _q():
        project(sq, uq, 0, roped=True)

    @pl.when(p == 1)
    def _k():
        project(sk, uk, 1, roped=True)

    @pl.when(p == 2)
    def _v():
        project(sv, uv, 2, roped=False)

    def _score_block(q_t, k_blk, r0, n):
        sc = jax.lax.dot_general(q_t, k_blk, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        sc = sc * scale
        if binarize_scores:
            a = (sc - delta_ref[0, 0] >= 0).astype(jnp.float32)
        else:
            a = sc
        if causal:
            rows = jax.lax.broadcasted_iota(jnp.int32, (l, n), 0)
            cols = jax.lax.broadcasted_iota(jnp.int32, (l, n), 1) + r0
            a = jnp.where(rows >= cols, a, 0.0)
        return a

    def _qkt_live(k_blk):
        # an all-dark key block scores to exact zeros, which binarize to
        # zero whenever delta > 0; when delta <= 0 (or scores stay
        # analog) the block must execute — the predicate says so, so the
        # skip stays exact (+0) by construction
        live = jnp.any(k_blk != 0)
        if binarize_scores:
            live = live | (delta_ref[0, 0] <= 0)
        else:
            live = live | True
        return live

    @pl.when(p == 3)
    def _qkt():
        # binary engine, score phase: binarized+masked score blocks land
        # in VMEM scratch for the qktv phase; dark blocks skip and the
        # skip is recorded in the occupancy map
        nexec = [jnp.int32(0)] * nlb
        for t in trange:
            q_t, k_t = sq[slot(t)], sk[slot(t)]
            a_t = jnp.zeros((l, l), jnp.float32)
            for lb, r0, r1 in blocks:
                k_blk = k_t[r0:r1]
                live = _qkt_live(k_blk)
                a_blk = jax.lax.cond(
                    live,
                    lambda q=q_t, kb=k_blk, r=r0, n=r1 - r0:
                        _score_block(q, kb, r, n),
                    lambda n=r1 - r0: jnp.zeros((l, n), jnp.float32))
                a_t = _patch(a_t, r0, r1, a_blk, axis=1)
                nexec[lb] += live.astype(jnp.int32)
            scr[slot(t)] = a_t
        _bump(3, nexec)

    @pl.when(p == 4)
    def _qktv():
        # binary engine, context phase: contract the stashed score
        # blocks with the value blocks; a block whose scores or value
        # spikes are all dark contributes exact +0 and is skipped
        nexec = [jnp.int32(0)] * nlb
        for t in trange:
            k_t, v_t = sk[slot(t)], sv[slot(t)]
            a_t = scr[slot(t)]
            ctx = jnp.zeros((l, head_dim), jnp.float32)
            for lb, r0, r1 in blocks:
                v_blk = v_t[r0:r1]
                live = _qkt_live(k_t[r0:r1]) & jnp.any(v_blk != 0)
                ctx = ctx + jax.lax.cond(
                    live,
                    lambda a=a_t[:, r0:r1], v=v_blk: jax.lax.dot_general(
                        a, v.astype(jnp.float32),
                        (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32),
                    lambda: jnp.zeros((l, head_dim), jnp.float32))
                nexec[lb] += live.astype(jnp.int32)
            ctxs[slot(t)] = ctx.astype(dtype)
        _bump(4, nexec)

    @pl.when(p == 5)
    def _wo():
        # sparse engine, output projection: head h's context slice times
        # wo's matching row block, fp32-accumulated across heads (exact:
        # binary-attention contexts are integer counts, weights dyadic);
        # dark context row blocks skip. The last head runs the epilogue:
        # quant scale, bn_o (vision) -> residual -> input neuron /
        # ln2 rmsnorm (token) into the MLP input scratch.
        w = wo_ref[...]                                  # (hd, D)
        nexec = [jnp.int32(0)] * nlb
        for t in trange:
            @pl.when(h == 0)
            def _zero():
                attn_acc[t] = jnp.zeros((l, d_model), jnp.float32)
            ctx_t = ctxs[slot(t)]
            for lb, r0, r1 in blocks:
                rows = ctx_t[r0:r1]
                occ = jnp.any(rows != 0)
                contrib = jax.lax.cond(
                    occ,
                    lambda r=rows: jax.lax.dot_general(
                        r, w, (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32),
                    lambda: jnp.zeros((r1 - r0, d_model), jnp.float32))
                attn_acc[t] = _patch(attn_acc[t], r0, r1, contrib, add=True)
                nexec[lb] += occ.astype(jnp.int32)

            @pl.when(h == num_heads - 1)
            def _epilogue():
                y = attn_acc[t] * sco_ref[0].astype(jnp.float32)
                y = y.astype(dtype)
                if family == "bn":
                    y32 = y.astype(jnp.float32)
                    y32 = ((y32 - auxo_ref[0])
                           * jax.lax.rsqrt(auxo_ref[1] + eps))
                    y = (y32 * auxo_ref[2] + auxo_ref[3]).astype(dtype)
                x1 = x_ref[0][t] + y                     # residual stream
                x1s[t] = x1
                if family == "bn":
                    s2s[t] = _lif(us2, 0, t, x1)         # input neuron
                else:                                    # ln2 (nn.rmsnorm)
                    x32 = x1.astype(jnp.float32)
                    var = jnp.mean(jnp.square(x32), axis=-1,
                                   keepdims=True)
                    s2s[t] = (x32 * jax.lax.rsqrt(var + norm_eps)
                              * auxo_ref[0].astype(jnp.float32)
                              ).astype(dtype)
        _bump(5, nexec)

    @pl.when(p == 6)
    def _up():
        # sparse engine, MLP up: ff-chunk h of w1 against the full-D
        # spike (vision) / normed-current (token) rows; epilogue
        # bn_1 + LIF (vision) or LIF (token) into the hidden spikes
        w = w1_ref[...]                                  # (D, ffc)
        nexec = [jnp.int32(0)] * nlb
        for t in trange:
            s2_t = s2s[t]
            cur = jnp.zeros((l, ffc), jnp.float32)
            for lb, r0, r1 in blocks:
                rows = s2_t[r0:r1]
                occ = jnp.any(rows != 0)
                acc = jax.lax.cond(
                    occ,
                    lambda r=rows: jax.lax.dot_general(
                        r, w, (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32),
                    lambda: jnp.zeros((r1 - r0, ffc), jnp.float32))
                cur = _patch(cur, r0, r1, acc)
                nexec[lb] += occ.astype(jnp.int32)
            cur = cur * sc1_ref[0].astype(jnp.float32)
            y_t = cur.astype(dtype)
            if family == "bn":
                y32 = y_t.astype(jnp.float32)
                y32 = ((y32 - aux1_ref[0])
                       * jax.lax.rsqrt(aux1_ref[1] + eps))
                y_t = (y32 * aux1_ref[2] + aux1_ref[3]).astype(dtype)
            hids[slot(t)] = _lif(uh, h, t, y_t)
        _bump(6, nexec)

    @pl.when(p == 7)
    def _down():
        # sparse engine, MLP down: ff-chunk h of w2 against chunk h's
        # hidden spikes, fp32-accumulated across chunks; the last chunk
        # runs the epilogue (quant scale, bn_2, residual) and writes
        # the layer output
        w = w2_ref[...]                                  # (ffc, D)
        nexec = [jnp.int32(0)] * nlb
        for t in trange:
            @pl.when(h == 0)
            def _zero():
                dn_acc[t] = jnp.zeros((l, d_model), jnp.float32)
            hid_t = hids[slot(t)]
            for lb, r0, r1 in blocks:
                rows = hid_t[r0:r1]
                occ = jnp.any(rows != 0)
                contrib = jax.lax.cond(
                    occ,
                    lambda r=rows: jax.lax.dot_general(
                        r, w, (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32),
                    lambda: jnp.zeros((r1 - r0, d_model), jnp.float32))
                dn_acc[t] = _patch(dn_acc[t], r0, r1, contrib, add=True)
                nexec[lb] += occ.astype(jnp.int32)

            @pl.when(h == num_heads - 1)
            def _epilogue():
                y = dn_acc[t] * sc2_ref[0].astype(jnp.float32)
                y = y.astype(dtype)
                if family == "bn":
                    y32 = y.astype(jnp.float32)
                    y32 = ((y32 - aux2_ref[0])
                           * jax.lax.rsqrt(aux2_ref[1] + eps))
                    y = (y32 * aux2_ref[2] + aux2_ref[3]).astype(dtype)
                pl.store(o_ref, (jnp.int32(0), jnp.asarray(t, jnp.int32),
                                 slice(None), slice(None)),
                         x1s[t] + y)
        _bump(7, nexec)


def fused_layer(x: jax.Array, s: jax.Array, w3: jax.Array, wo: jax.Array,
                w1: jax.Array, w2: jax.Array,
                scales: Optional[Tuple[jax.Array, jax.Array, jax.Array,
                                       jax.Array]],
                auxp: jax.Array, auxo: jax.Array,
                aux1: Optional[jax.Array], aux2: Optional[jax.Array],
                delta, *, family: str, num_heads: int, head_dim: int,
                scale: float, causal: bool = False, sparse: str = "tile",
                pipeline: bool = False, binarize_scores: bool = True,
                decay: float = 0.5, v_th: float = 1.0,
                soft_reset: bool = False, eps: float = 1e-5,
                norm_eps: float = 1e-6, l_block: int = 128,
                c_block: int = 128, interpret: Optional[bool] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Fused whole-layer step (forward only — ``core.engine`` wraps it
    in a custom VJP whose bwd recomputes :func:`reference_layer`).

    Args:
      x: ``(T, B, L, D)`` layer input — membrane currents, the residual
        stream (activation dtype).
      s: ``(T, B, L, D)`` projection-phase input: ``LIF(x)`` spikes
        (vision family) or the ln1-normed currents (token family).
      w3: ``(3, D, H*hd)`` stacked Q/K/V weights; wo ``(H*hd, D)``;
        w1 ``(D, F)``; w2 ``(F, D)`` with F = d_ff padded to a multiple
        of ``num_heads`` (zero pad — exact: padded channels normalize
        to zero through identity BN rows and never spike). Quantized
        codes arrive pre-cast to the activation dtype.
      scales: ``(scale3 (3, H*hd), scale_o (D,), scale_1 (F,),
        scale_2 (D,))`` fp32 per-channel quantization scales, or
        ``None`` for fp-native weights (multiplying fp32 by 1.0 is a
        bitwise identity, so the uniform kernel signature is free).
      auxp: projection epilogue — family ``'bn'``: ``(3, 4, H*hd)``
        rows [mean, var, scale, bias]; family ``'rope'``: ``(2, L,
        hd//2)`` [cos; sin] tables.
      auxo / aux1 / aux2: family ``'bn'``: the bn_o ``(4, D)``, bn_1
        ``(4, F)``, bn_2 ``(4, D)`` eval rows; family ``'rope'``: auxo
        is the ln2 rmsnorm scale ``(1, D)`` and aux1/aux2 are ignored.
      sparse: ``'tile'`` (L-block occupancy skip) or ``'decoded'``
        (gather-compacted projection contraction; spike-driven family
        only — see module docstring).
      pipeline: run the ``(B, T, P, H)`` per-timestep wavefront grid
        instead of ``(B, P, H)``; outputs and counts are identical.

    Returns:
      (layer output ``(T, B, L, D)`` activation dtype,
       counts ``(H, 8, ceil(L / l_block))`` int32 — *executed* compute
       sub-blocks per head, phase (:data:`LAYER_PHASES`), L-block).
    """
    if family not in FAMILIES:
        raise ValueError(f"unknown fused-layer family {family!r} "
                         f"(expected bn|rope)")
    if sparse not in ("tile", "decoded"):
        raise ValueError(f"unknown fused-layer sparse path {sparse!r}")
    t, b, l, k_dim = x.shape
    d_model = k_dim
    q_dim = num_heads * head_dim
    assert w3.shape == (3, k_dim, q_dim), w3.shape
    assert wo.shape == (q_dim, d_model), wo.shape
    ff = w1.shape[1]
    assert ff % num_heads == 0, "pad d_ff to a multiple of num_heads"
    ffc = ff // num_heads
    assert w2.shape == (ff, d_model), w2.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    dtype = x.dtype
    l_block = max(1, min(l_block, l))
    nlb = -(-l // l_block)
    # the decoded gather needs exact operands for order-free fp32
    # accumulation; the token family's projection input is analog
    decoded = sparse == "decoded" and family == "bn"
    delta_op = jnp.asarray(delta, jnp.float32).reshape(1, 1)

    xb = jnp.transpose(x, (1, 0, 2, 3))              # (B, T, L, D)
    sb = jnp.transpose(s, (1, 0, 2, 3))

    if scales is None:
        scales = (jnp.ones((3, q_dim), jnp.float32),
                  jnp.ones((d_model,), jnp.float32),
                  jnp.ones((ff,), jnp.float32),
                  jnp.ones((d_model,), jnp.float32))
    sc3, sco, sc1, sc2 = (jnp.asarray(a, jnp.float32) for a in scales)

    if pipeline:
        grid = (b, t, N_PHASES, num_heads)
        ix = lambda f: (lambda bi, ti, pi, hi: f(bi, pi, hi))
    else:
        grid = (b, N_PHASES, num_heads)
        ix = lambda f: (lambda bi, pi, hi: f(bi, pi, hi))

    in_specs = [
        pl.BlockSpec((1, t, l, d_model),
                     ix(lambda bi, pi, hi: (bi, 0, 0, 0))),
        pl.BlockSpec((1, t, l, d_model),
                     ix(lambda bi, pi, hi: (bi, 0, 0, 0))),
        pl.BlockSpec((1, k_dim, head_dim),
                     ix(lambda bi, pi, hi: (jnp.minimum(pi, 2), 0, hi))),
        pl.BlockSpec((head_dim, d_model), ix(lambda bi, pi, hi: (hi, 0))),
        pl.BlockSpec((k_dim, ffc), ix(lambda bi, pi, hi: (0, hi))),
        pl.BlockSpec((ffc, d_model), ix(lambda bi, pi, hi: (hi, 0))),
        pl.BlockSpec((1, head_dim),
                     ix(lambda bi, pi, hi: (jnp.minimum(pi, 2), hi))),
        pl.BlockSpec((1, d_model), ix(lambda bi, pi, hi: (0, 0))),
        pl.BlockSpec((1, ffc), ix(lambda bi, pi, hi: (0, hi))),
        pl.BlockSpec((1, d_model), ix(lambda bi, pi, hi: (0, 0))),
    ]
    operands = [xb, sb, w3, wo, w1, w2, sc3, sco.reshape(1, d_model),
                sc1.reshape(1, ff), sc2.reshape(1, d_model)]
    if family == "bn":
        assert auxp.shape == (3, 4, q_dim), auxp.shape
        assert auxo.shape == (4, d_model), auxo.shape
        assert aux1.shape == (4, ff), aux1.shape
        assert aux2.shape == (4, d_model), aux2.shape
        in_specs += [
            pl.BlockSpec((1, 4, head_dim),
                         ix(lambda bi, pi, hi:
                            (jnp.minimum(pi, 2), 0, hi))),
            pl.BlockSpec((4, d_model), ix(lambda bi, pi, hi: (0, 0))),
            pl.BlockSpec((4, ffc), ix(lambda bi, pi, hi: (0, hi))),
            pl.BlockSpec((4, d_model), ix(lambda bi, pi, hi: (0, 0))),
        ]
    else:
        assert auxp.shape == (2, l, head_dim // 2), auxp.shape
        assert auxo.shape == (1, d_model), auxo.shape
        aux1 = jnp.zeros((1, 1), jnp.float32)
        aux2 = jnp.zeros((1, 1), jnp.float32)
        in_specs += [
            pl.BlockSpec((2, l, head_dim // 2),
                         ix(lambda bi, pi, hi: (0, 0, 0))),
            pl.BlockSpec((1, d_model), ix(lambda bi, pi, hi: (0, 0))),
            pl.BlockSpec((1, 1), ix(lambda bi, pi, hi: (0, 0))),
            pl.BlockSpec((1, 1), ix(lambda bi, pi, hi: (0, 0))),
        ]
    operands += [auxp.astype(jnp.float32), auxo.astype(jnp.float32),
                 aux1.astype(jnp.float32), aux2.astype(jnp.float32)]
    in_specs.append(pl.BlockSpec((1, 1), ix(lambda bi, pi, hi: (0, 0))))
    operands.append(delta_op)

    nc = 1
    c_blk = c_block
    if decoded:
        from repro.kernels.spike_decode import slab_decode
        idx, vals, caps, c_blk = slab_decode(s, l_block=l_block,
                                             c_block=c_block)
        cp = idx.shape[-1]
        nc = cp // c_blk
        in_specs += [
            pl.BlockSpec((1, t, l, cp),
                         ix(lambda bi, pi, hi: (bi, 0, 0, 0))),
            pl.BlockSpec((1, t, l, cp),
                         ix(lambda bi, pi, hi: (bi, 0, 0, 0))),
            pl.BlockSpec((1, t, nlb),
                         ix(lambda bi, pi, hi: (bi, 0, 0))),
        ]
        operands += [idx, vals, caps]

    kernel = functools.partial(
        _kernel, family=family, decoded=decoded, pipeline=pipeline,
        t_steps=t, l=l, k_dim=k_dim, d_model=d_model, head_dim=head_dim,
        num_heads=num_heads, ffc=ffc, l_block=l_block, c_block=c_blk,
        nc=nc, nlb=nlb, scale=float(scale), causal=causal,
        binarize_scores=binarize_scores, decay=float(decay),
        v_th=float(v_th), soft_reset=soft_reset, eps=float(eps),
        norm_eps=float(norm_eps), dtype=dtype)

    out, cnt = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, t, l, d_model),
                         ix(lambda bi, pi, hi: (bi, 0, 0, 0))),
            pl.BlockSpec((num_heads, N_PHASES, nlb),
                         ix(lambda bi, pi, hi: (0, 0, 0))),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, l, d_model), dtype),
            jax.ShapeDtypeStruct((num_heads, N_PHASES, nlb), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((num_heads * t, l, head_dim), dtype),  # q spikes
            pltpu.VMEM((num_heads * t, l, head_dim), dtype),  # k spikes
            pltpu.VMEM((num_heads * t, l, head_dim), dtype),  # v spikes
            pltpu.VMEM((num_heads * t, l, l), jnp.float32),   # scores
            pltpu.VMEM((num_heads * t, l, head_dim), dtype),  # contexts
            pltpu.VMEM((num_heads * t, l, ffc), dtype),       # mlp hidden
            pltpu.VMEM((t, l, d_model), jnp.float32),         # wo accum
            pltpu.VMEM((t, l, d_model), jnp.float32),         # down accum
            pltpu.VMEM((t, l, d_model), dtype),               # x + attn
            pltpu.VMEM((t, l, d_model), dtype),               # mlp input
            pltpu.VMEM((num_heads, l, head_dim), dtype),      # q membrane
            pltpu.VMEM((num_heads, l, head_dim), dtype),      # k membrane
            pltpu.VMEM((num_heads, l, head_dim), dtype),      # v membrane
            pltpu.VMEM((1, l, d_model), dtype),               # s2 membrane
            pltpu.VMEM((num_heads, l, ffc), dtype),           # mlp membrane
        ],
        interpret=interpret,
    )(*operands)
    return jnp.transpose(out, (1, 0, 2, 3)), cnt


def reference_layer(x: jax.Array, s: jax.Array, w3, wo, w1, w2,
                    scales, auxp, auxo, aux1, aux2, delta,
                    scfg: SpikingConfig, *, family: str, num_heads: int,
                    head_dim: int, scale: float, causal: bool = False,
                    eps: float = 1e-5, norm_eps: float = 1e-6
                    ) -> jax.Array:
    """The sequential oracle: term-for-term the ``overlap='off'`` layer
    composition (the SSA bundle via ``fused_ssa.reference_bundle``, then
    wo + epilogue + residual, input neuron / ln2, and the spiking MLP)
    on the same raw operands the kernel sees. The fused custom VJP
    recomputes through this in bwd, so fused-layer gradients are the
    sequential path's gradients by construction (surrogate LIF /
    binarize jvps included)."""
    from repro.kernels.fused_ssa import reference_bundle
    if scales is None:
        sc3 = sco = sc1 = sc2 = None
    else:
        sc3, sco, sc1, sc2 = scales

    def lin(u, w, sc):
        acc = jnp.dot(u, w, preferred_element_type=jnp.float32)
        if sc is not None:
            acc = acc * sc.astype(jnp.float32)
        return acc.astype(u.dtype)

    def bn(u, aux):
        u32 = u.astype(jnp.float32)
        u32 = (u32 - aux[0]) * jax.lax.rsqrt(aux[1] + eps)
        return (u32 * aux[2] + aux[3]).astype(x.dtype)

    ctx = reference_bundle(s, w3, sc3, auxp, delta, scfg, family=family,
                           num_heads=num_heads, head_dim=head_dim,
                           scale=scale, causal=causal, eps=eps)
    y = lin(ctx, wo, sco)
    if family == "bn":
        y = bn(y, auxo)
    x1 = x + y
    if family == "bn":
        s2, _ = lif_scan(x1, scfg)
    else:
        x32 = x1.astype(jnp.float32)
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        s2 = (x32 * jax.lax.rsqrt(var + norm_eps)
              * auxo[0].astype(jnp.float32)).astype(x.dtype)
    up = lin(s2, w1, sc1)
    if family == "bn":
        up = bn(up, aux1)
    hid, _ = lif_scan(up, scfg)
    dn = lin(hid, w2, sc2)
    if family == "bn":
        dn = bn(dn, aux2)
    return x1 + dn
