"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attention + mamba heads
[arXiv:2411.13676; hf]. Meta-tokens omitted (DESIGN.md §5); 25 heads not
divisible by the 16-way model axis => head-replicated TP, d_ff/d_inner
sharded instead."""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5, head_dim=64,
    d_ff=5504, vocab_size=32001,
    attn_type="full", act="silu", gated=True, rope_theta=10000.0,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=5, num_kv_heads=1, head_dim=16,
    d_ff=128, vocab_size=512, dtype="float32", remat=False,
    ssm=SSMConfig(d_state=4, d_conv=4, expand=2))
