"""nemotron-4-15b [dense]: 32L d_model=6144 48H (GQA kv=8) d_ff=24576
vocab=256000 — GQA, squared-ReLU, non-gated MLP [arXiv:2402.16819]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b", family="dense",
    num_layers=32, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=256000,
    attn_type="full", act="relu2", gated=False, rope_theta=10000.0,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=128, num_heads=6, num_kv_heads=2, head_dim=16,
    d_ff=256, vocab_size=512, dtype="float32", remat=False)
