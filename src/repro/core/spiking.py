"""Spiking neural dynamics: LIF neurons, surrogate gradients, binarization.

The paper's workloads are spiking transformers (Spikingformer family) trained
with BrainCog and deployed on FireFly-T. This module provides the neural
dynamics substrate:

* ``spike``            — Heaviside with a sigmoid surrogate gradient
                         (``custom_jvp`` so both fwd- and rev-mode work).
* ``lif_scan``         — multi-step Leaky Integrate-and-Fire over the time
                         axis (``lax.scan``), soft or hard reset.
* ``binarize``         — learnable-threshold binarization used by binary
                         attention (Shen et al. [17] / BESTformer [18]).
* ``SpikingConfig``    — the knob models use to switch spiking mode on.

Parameterization notes (faithfulness): Spikingformer uses LIF with
``tau = 2.0`` (decay 0.5), threshold 1.0 and hard reset in SpikingJelly /
BrainCog; we default to the same but keep soft reset available (FireFly-T's
neuron module supports both; soft reset is what the accumulate-subtract
hardware in FireFly v2 implements).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SpikingConfig:
    """Configuration for spiking execution of a model."""

    time_steps: int = 4          # T_s
    tau: float = 2.0             # membrane time constant; decay = 1 - 1/tau
    v_threshold: float = 1.0
    soft_reset: bool = False     # Spikingformer default: hard reset
    surrogate_alpha: float = 4.0
    attention: bool = True       # enable binary attention (the binary engine)
    attn_threshold_init: float = 0.3  # learnable Delta init for binarization
    binarize_scores: bool = True      # binarize QK^T (binary attention [17])
    binarize_context: bool = False    # additionally binarize (QK^T)V

    @property
    def decay(self) -> float:
        return 1.0 - 1.0 / self.tau


# ---------------------------------------------------------------------------
# Surrogate-gradient spike function
# ---------------------------------------------------------------------------

@partial(jax.custom_jvp, nondiff_argnums=(1,))
def spike(v: jax.Array, alpha: float = 4.0) -> jax.Array:
    """Heaviside step ``1[v >= 0]`` with sigmoid surrogate gradient.

    Forward: exact step function (binary output, same dtype as ``v``).
    Backward: d/dv sigmoid(alpha * v) = alpha * s * (1 - s).
    """
    return (v >= 0).astype(v.dtype)


@spike.defjvp
def _spike_jvp(alpha, primals, tangents):
    (v,), (dv,) = primals, tangents
    out = spike(v, alpha)
    s = jax.nn.sigmoid(alpha * v)
    grad = alpha * s * (1.0 - s)
    return out, grad * dv


def binarize(x: jax.Array, delta: jax.Array, alpha: float = 4.0) -> jax.Array:
    """Thresholded binarization ``1[x > delta]`` with surrogate gradient.

    ``delta`` is the learnable threshold of binary attention; gradients flow
    to both ``x`` and ``delta`` through the surrogate.
    """
    return spike(x - delta, alpha)


# ---------------------------------------------------------------------------
# LIF dynamics
# ---------------------------------------------------------------------------

def lif_step(u: jax.Array, x: jax.Array, *, decay: float, v_th: float,
             soft_reset: bool, alpha: float):
    """One LIF update. Returns (new_membrane, spikes)."""
    u = decay * u + x
    s = spike(u - v_th, alpha)
    if soft_reset:
        u = u - s * v_th
    else:
        u = u * (1.0 - s)
    return u, s


def lif_scan(currents: jax.Array, cfg: SpikingConfig,
             v0: Optional[jax.Array] = None):
    """Run LIF dynamics over the leading time axis.

    Args:
      currents: ``(T, ...)`` input currents.
      cfg: spiking configuration.
      v0: optional initial membrane ``(...)``; zeros if None.

    Returns:
      (spikes ``(T, ...)``, final membrane ``(...)``).
    """
    def step(u, x):
        u, s = lif_step(u, x, decay=cfg.decay, v_th=cfg.v_threshold,
                        soft_reset=cfg.soft_reset, alpha=cfg.surrogate_alpha)
        return u, s

    u0 = jnp.zeros_like(currents[0]) if v0 is None else v0
    u_final, spikes = jax.lax.scan(step, u0, currents)
    return spikes, u_final


def lif_loop_reference(currents, cfg: SpikingConfig, v0=None):
    """Pure-python LIF loop — oracle for tests (identical math, no scan)."""
    u = jnp.zeros_like(currents[0]) if v0 is None else v0
    outs = []
    for t in range(currents.shape[0]):
        u, s = lif_step(u, currents[t], decay=cfg.decay, v_th=cfg.v_threshold,
                        soft_reset=cfg.soft_reset, alpha=cfg.surrogate_alpha)
        outs.append(s)
    return jnp.stack(outs), u


# ---------------------------------------------------------------------------
# Spike encodings
# ---------------------------------------------------------------------------

def rate_encode(x: jax.Array, time_steps: int, key: jax.Array) -> jax.Array:
    """Bernoulli rate coding: ``(...,) -> (T, ...)`` binary spikes."""
    p = jnp.clip(x, 0.0, 1.0)
    u = jax.random.uniform(key, (time_steps,) + x.shape, dtype=x.dtype)
    return (u < p).astype(x.dtype)


def direct_encode(x: jax.Array, time_steps: int) -> jax.Array:
    """Direct coding: replicate analog input across T (Spikingformer SPS
    input convention — the first conv layer consumes the analog image)."""
    return jnp.broadcast_to(x[None], (time_steps,) + x.shape)


def measure_sparsity(spikes: jax.Array) -> jax.Array:
    """Fraction of zero entries (the paper's Fig. 11 metric)."""
    return 1.0 - jnp.mean(spikes.astype(jnp.float32))
