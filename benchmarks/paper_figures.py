"""Paper-table/figure benchmarks (one function per artifact).

Each returns (rows, derived) where rows are CSV-able dicts and derived is
the headline scalar(s) the paper claims for that artifact.
"""
from __future__ import annotations

import sys
import time

import numpy as np

sys.path.insert(0, "src")

from repro.sim import balance_sim as bs     # noqa: E402
from repro.sim import decoder_sim as ds     # noqa: E402
from repro.sim import perf_model as pm      # noqa: E402
from repro.sim import resource_model as rm  # noqa: E402
from repro.core.dual_engine import (AttentionWorkload,     # noqa: E402
                                    EngineParallelism, pipeline_schedule)


def fig11_sparsity():
    """Layer-wise spike sparsity of the paper's workloads (Fig. 11).

    Measured on smoke-scale models after a short training settle (CPU);
    the paper's claim: high (>=75%) and stable natural sparsity."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.data import DataConfig, make_pipeline
    from repro.launch.steps import build_train_step
    from repro.models import registry
    from repro.models.spikingformer import layer_sparsities
    from repro.optim import adamw

    rows = []
    for arch in ("spikingformer-4-256", "cifarnet"):
        cfg = get_config(arch, smoke=True)
        params = registry.init(cfg, jax.random.PRNGKey(0))
        state = registry.init_state(cfg)
        opt = adamw(1e-3)
        opt_state = opt.init(params)
        data = make_pipeline(DataConfig(
            kind="images", global_batch=8, img_size=cfg.vision.img_size,
            num_classes=cfg.vocab_size))
        step = jax.jit(build_train_step(cfg, opt))
        s = jnp.asarray(0)
        for i in range(10):
            b = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
            params, opt_state, s, _, state = step(params, opt_state, s, b,
                                                  state)
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(99).items()}
        sps = layer_sparsities(params, cfg, batch, state)
        for name, val in sps:
            rows.append({"bench": "fig11", "network": arch, "layer": name,
                         "sparsity": round(val, 4)})
    mean_sp = float(np.mean([r["sparsity"] for r in rows]))
    return rows, {"mean_sparsity": round(mean_sp, 3),
                  "paper_claim": ">=0.75 (trained nets)"}


def fig12_decoder():
    out, best = ds.sweep_fig12(g_values=(2, 4, 8),
                               p_ci_values=(4, 8, 16, 32, 64, 128),
                               sparsity=0.75)
    rows = [{"bench": "fig12", "G": g, "P_Ci": p, "F_norm": round(v, 4)}
            for g, curve in out.items() for p, v in curve.items()]
    return rows, {"optimal_P_Ci": best,
                  "paper_claim": "P_Ci* = G/(1-s) = {2:8, 4:16, 8:32}"}


def fig13_balance():
    rows = []
    for g, p_ci in ((4, 16), (8, 32)):
        r = ds.sweep_fig13a(g, p_ci)
        peak = max(r.values())
        for pwo, v in r.items():
            rows.append({"bench": "fig13a", "G": g, "P_Wo": pwo,
                         "R_frac_of_peak": round(v / peak, 4)})
    res1 = bs.compare(n_pes=16, n_banks=1, throughput=4)
    ours, xbar = bs.scaling_curve()
    for p in ours:
        rows.append({"bench": "fig13c", "PEs": p,
                     "ours_norm": round(ours[p], 4),
                     "crossbar_norm": round(xbar[p], 4)})
    derived = {
        "bm1_speedup": round(res1.speedup, 2),
        "ours_loss_128pe_pct": round(100 * (1 - ours[128]), 1),
        "crossbar_loss_128pe_pct": round(100 * (1 - xbar[128]), 1),
        "paper_claims": "3.48x; 13.17%; 70.68%",
    }
    return rows, derived


def table4_comparison():
    rows = []
    for net, hw in (("cifarnet", rm.HardwareConfig(g=2)),
                    ("spikingformer-4-256", rm.HardwareConfig(g=4)),
                    ("spikingformer-8-512", rm.HardwareConfig(g=4))):
        r = pm.evaluate(net, hw)
        pub = {"cifarnet": "fireflyt_cifarnet",
               "spikingformer-4-256": "fireflyt_sf4_256",
               "spikingformer-8-512": "fireflyt_sf8_512"}[net]
        paper = pm.PUBLISHED[pub]
        rows.append({"bench": "table4", "network": net,
                     "gops_model": round(r.gops, 0),
                     "gops_paper": paper["gops"],
                     "fps_model": round(r.fps, 0),
                     "energy_eff_model": round(r.energy_eff, 1),
                     "energy_eff_paper": paper["energy_eff"],
                     "dsp_eff_model": round(r.dsp_eff, 2),
                     "dsp_eff_paper": paper["dsp_eff"],
                     "attention_hidden": round(r.hidden_attention_frac, 2)})
    ratios = {k: round(v, 2) for k, v in pm.headline_ratios().items()}
    ratios["paper_claims"] = "1.39x / 2.40x energy; 4.21x / 7.10x DSP"
    return rows, ratios


def table56_resources():
    rows = []
    for g in (2, 4):
        hw = rm.HardwareConfig(g=g, p_wo=2)
        br = rm.resource_breakdown(hw)
        for comp, vals in br.items():
            rows.append({"bench": "table5", "G": g, "component": comp,
                         **{k: (round(v, 2) if isinstance(v, float) else v)
                            for k, v in vals.items()}})
        sv = rm.dsp_savings(hw)
        rows.append({"bench": "table6", "G": g, **sv})
    c = rm.and_popcount_comparison(18)
    derived = {"fig9_depth": f"{c['naive_depth']}->{c['ours_depth']} "
               "(paper 5->2)",
               "fig9_lut_reduction": round(c["lut_reduction"], 3),
               "paper_lut_reduction": 0.52,
               "decoder_luts_G4_model_vs_paper":
               f"{rm.decoder_luts(rm.HardwareConfig(g=4))} vs 1442"}
    return rows, derived


def fig5_pipeline():
    w = AttentionWorkload(T_s=4, F_h=14, F_w=14, C_i=512, P_Co=64, heads=8)
    p = EngineParallelism(P_Ts=2, P_Fx=4, P_Ci=16, P_Co=64,
                          P_Bm=8, P_Bn=8, P_Bk=32)
    se, be, overlapped, serial = pipeline_schedule(w, p)
    rows = [{"bench": "fig5", "engine": "sparse", "op": n,
             "start": round(s, 1), "end": round(e, 1)} for n, s, e in se[:6]]
    rows += [{"bench": "fig5", "engine": "binary", "op": n,
              "start": round(s, 1), "end": round(e, 1)} for n, s, e in be[:4]]
    return rows, {"overlapped_cycles": overlapped, "serial_cycles": serial,
                  "hiding_gain": round(serial / overlapped, 3)}


def kernels_bench():
    """Kernel wall times (CPU interpret mode = functional check only; the
    derived column contrasts the MXU formulation vs the bit-packed
    popcount port — the DESIGN.md §3 adaptation argument)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops

    def timeit(fn, *args, n=3):
        fn(*args)  # compile/warm
        ts = []
        for _ in range(n):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            ts.append(time.perf_counter() - t0)
        return min(ts) * 1e6

    key = jax.random.PRNGKey(0)
    bh, l, d = 4, 128, 64
    ks = jax.random.split(key, 3)
    mk = lambda k: (jax.random.uniform(k, (bh, l, 1, d)) > 0.75
                    ).astype(jnp.float32)
    q, k_, v = mk(ks[0]), mk(ks[1]), mk(ks[2])
    attn = jax.jit(lambda q, k, v: ops.spike_attention(
        q, k, v, scale=0.125, delta=0.3, causal=False))
    t_attn = timeit(attn, q, k_, v)
    qs = q.reshape(bh, l, d)
    ks_ = k_.reshape(bh, l, d)
    pop = jax.jit(lambda a, b: ops.popcount_attention_scores(a, b))
    t_pop = timeit(pop, qs, ks_)
    from repro.models.nn import binary_flash_attention
    jref = jax.jit(lambda q, k, v: binary_flash_attention(
        q, k, v, delta=0.3, alpha=4.0, causal=False, q_chunk=64,
        kv_chunk=64))
    t_ref = timeit(jref, q, k_, v)
    s = (jax.random.uniform(ks[0], (256, 256)) > 0.75).astype(jnp.float32)
    w = jax.random.normal(ks[1], (256, 128))
    mm = jax.jit(lambda s, w: ops.spike_matmul(s, w, block_m=128,
                                               block_n=128, block_k=128))
    t_mm = timeit(mm, s, w)
    lif_in = jax.random.normal(ks[2], (4, 256, 512))
    lf = jax.jit(lambda x: ops.lif(x, decay=0.5))
    t_lif = timeit(lf, lif_in)
    rows = [
        {"bench": "kernels", "kernel": "spike_attention(interp)",
         "us_per_call": round(t_attn, 1)},
        {"bench": "kernels", "kernel": "popcount_scores(interp)",
         "us_per_call": round(t_pop, 1)},
        {"bench": "kernels", "kernel": "binary_flash_jnp",
         "us_per_call": round(t_ref, 1)},
        {"bench": "kernels", "kernel": "spike_matmul(interp)",
         "us_per_call": round(t_mm, 1)},
        {"bench": "kernels", "kernel": "lif(interp)",
         "us_per_call": round(t_lif, 1)},
    ]
    return rows, {"note": "interpret-mode wall times (CPU container); "
                  "MXU-vs-popcount contrast is structural, see DESIGN §3"}
