"""Symmetric integer weight quantization — the sparse engine's missing half.

FireFly-T's sparse engine multiplies binary spikes against *low-precision
integer weights*: the 4.21x/7.10x DSP-efficiency wins over FireFly v2 and
SpikeTA come from packing an int8-weight x AND-gated datapath onto the
DSP48s, and FireFly-S makes dual-side (spike + weight) compression the
design center. This module is the TPU mapping of the weight side
(DESIGN.md §8): fp32/bf16 param trees become

    {"qw": int8 (…, K, N),          "scale": fp32 (…, N) [, "b"]}   int8
    {"qw": uint8 (…, ceil(K/2), N), "scale": fp32 (…, N) [, "b"]}   int4

with *per-output-channel* symmetric scales (scale[n] = amax_k |w[k, n]| /
qmax): the channel axis is the kernel's N tile, so the scale applies as a
cheap per-column epilogue multiply after int32 accumulation — exactly the
per-filter shift-add FireFly-T's DSP epilogue performs. int4 packs two
two's-complement nibbles per uint8 byte along K (the reduction axis), the
byte-level analogue of the paper's spike-word packing.

Dyadic mode rounds every scale *up* to a power of two. Then dequantized
weights ``qw * 2^-e`` are exact fp32 numbers and every spike-matmul
partial sum is an integer times ``2^-e`` (exact in fp32 up to 2^24), so
the int32-accumulating kernel and the fp32 reference on dequantized
weights agree **bitwise** — the property tests/test_quant.py pins. It is
also the FPGA-faithful mode: a power-of-two scale is a barrel shift, not
a multiplier.

Leading axes beyond (K, N) are scan-stacked layer dims (the repo stacks
per-layer params for ``lax.scan``); channels stay the last axis and K the
second-to-last throughout.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

INT_BITS = {"int8": 8, "int4": 4}
QMAX = {8: 127, 4: 7}
_EPS = 1e-12


def qmax_for(bits: int) -> int:
    return QMAX[bits]


def symmetric_scale(x: jax.Array, bits: int, *, axis=None,
                    dyadic: bool = False,
                    clip_ratio: float = 1.0) -> jax.Array:
    """Symmetric quantization scale: ``amax(|x|) * clip_ratio / qmax``.

    ``axis=None`` -> per-tensor scalar (the gradient-compression layout);
    ``axis=-2`` -> per-output-channel over the K axis of a (…, K, N)
    weight. ``dyadic`` rounds the scale up to the next power of two
    (``2^ceil(log2 s)``), keeping |q| <= qmax while making the scale an
    exact fp32 value.
    """
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis)
    scale = jnp.maximum(amax * clip_ratio, _EPS) / QMAX[bits]
    if dyadic:
        # ldexp, not exp2: XLA lowers exp2(x) as exp(x * ln 2), which is
        # 1 ulp off an exact power of two — ldexp builds the exponent
        # bits directly, and the bitwise-parity argument needs the scale
        # to BE a power of two, not to be near one
        e = jnp.ceil(jnp.log2(scale)).astype(jnp.int32)
        scale = jnp.ldexp(jnp.ones_like(scale), e)
    return scale


def quantize_values(x: jax.Array, scale: jax.Array, bits: int) -> jax.Array:
    """Round-to-nearest symmetric quantization -> int8-valued array in
    [-qmax, qmax] (int4 values also ride int8 until packed)."""
    q = jnp.round(x.astype(jnp.float32) / scale)
    return jnp.clip(q, -QMAX[bits], QMAX[bits]).astype(jnp.int8)


def dequantize_values(q: jax.Array, scale: jax.Array,
                      dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# int4 nibble packing (two's complement, two values per uint8 byte along K)
# ---------------------------------------------------------------------------


def pack_int4(q: jax.Array) -> jax.Array:
    """(…, K, N) int8 values in [-8, 7] -> (…, ceil(K/2), N) uint8.

    Byte layout: low nibble = even K row, high nibble = odd K row, both
    two's complement. An odd K pads one zero row (zero is quantization-
    neutral: it dequantizes to exact 0.0 and the unpack slices it off).
    """
    k = q.shape[-2]
    if k % 2:
        pad = [(0, 0)] * q.ndim
        pad[-2] = (0, 1)
        q = jnp.pad(q, pad)
    u = q.astype(jnp.uint8) & jnp.uint8(0xF)
    lo = u[..., 0::2, :]
    hi = u[..., 1::2, :]
    return lo | (hi << 4)


def unpack_int4(packed: jax.Array, k: int) -> jax.Array:
    """Inverse of :func:`pack_int4`: (…, ceil(k/2), N) uint8 -> (…, k, N)
    int8 (sign-extended nibbles; the K padding row is dropped)."""
    lo = packed & jnp.uint8(0xF)
    hi = packed >> 4
    pairs = jnp.stack([lo, hi], axis=-2)            # (…, P, 2, N)
    inter = pairs.reshape(*packed.shape[:-2], 2 * packed.shape[-2],
                          packed.shape[-1])
    signed = (inter.astype(jnp.int8) ^ jnp.int8(8)) - jnp.int8(8)
    return signed[..., :k, :]


# ---------------------------------------------------------------------------
# single weight / param-dict quantization
# ---------------------------------------------------------------------------


def quantize_weight(w: jax.Array, dtype: str = "int8", *,
                    dyadic: bool = False,
                    clip_ratio: float = 1.0) -> Dict[str, jax.Array]:
    """(…, K, N) weight -> {"qw", "scale"} with per-output-channel scales.

    int8 keeps ``qw`` as int8 (one byte per weight); int4 packs two
    nibbles per byte (``qw`` uint8, half the K rows) — but only for even
    K, so the packed shape alone recovers K exactly (an odd-K int4
    linear keeps int8-stored 4-bit codes: numerically identical, just
    without the packing win; real layer widths are even). No metadata
    leaf is stored — a quantized dict stays a pure array pytree.
    """
    bits = INT_BITS[dtype]
    scale = symmetric_scale(w, bits, axis=-2, dyadic=dyadic,
                            clip_ratio=clip_ratio)
    q = quantize_values(w, scale[..., None, :], bits)
    if dtype == "int4" and w.shape[-2] % 2 == 0:
        q = pack_int4(q)
    return {"qw": q, "scale": scale.astype(jnp.float32)}


def weight_bits(p: Dict[str, Any]) -> int:
    """4 or 8, inferred from the packed dtype (uint8 = packed nibbles)."""
    return 4 if p["qw"].dtype == jnp.uint8 else 8


def dequantize_weight(p: Dict[str, Any], k: Optional[int] = None,
                      dtype=jnp.float32) -> jax.Array:
    """{"qw","scale"} -> (…, K, N) weights. Packed int4 only ever holds
    even K (quantize_weight), so K = 2 * packed rows exactly; ``k``
    remains accepted for callers that know it (dispatch passes the
    activation's trailing dim)."""
    qw = p["qw"]
    if qw.dtype == jnp.uint8:
        qw = unpack_int4(qw, 2 * qw.shape[-2] if k is None else k)
    return dequantize_values(qw, p["scale"][..., None, :], dtype)


def is_quantized(p: Any) -> bool:
    return isinstance(p, dict) and "qw" in p


# ---------------------------------------------------------------------------
# tree quantization
# ---------------------------------------------------------------------------


def _is_linear_params(node: Any) -> bool:
    """A quantizable linear param dict: {"w": (…, K, N) [, "b"]} with a
    2-D weight or scan-stacked 3-D weight. Conv kernels (4-D), embedding
    tables ("table"), and norm scales don't match."""
    return (isinstance(node, dict) and "w" in node
            and hasattr(node["w"], "ndim") and node["w"].ndim in (2, 3))


def map_param_dicts(tree: Any, predicate: Callable[[Any], bool],
                    fn: Callable[[str, Any], Any]) -> Any:
    """Rebuild a param tree, applying ``fn('/'-joined path, node)`` to
    every dict node matching ``predicate`` and recursing through other
    dicts/lists/tuples — the one container walk behind quantize_tree /
    dequantize_tree / qat.fake_quant_tree."""
    def walk(path, node):
        if predicate(node):
            return fn("/".join(path), node)
        if isinstance(node, dict):
            return {k: walk(path + (str(k),), v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            seq = [walk(path + (str(i),), v) for i, v in enumerate(node)]
            return type(node)(seq) if isinstance(node, tuple) else seq
        return node
    return walk((), tree)


def quantize_tree(params: Any, dtype: str = "int8", *,
                  dyadic: bool = False, clip_ratio: float = 1.0,
                  select: Optional[Callable[[str], bool]] = None) -> Any:
    """Quantize every eligible linear in a param tree.

    Eligible nodes are ``{"w": (…, K, N)[, "b"]}`` dicts (see
    ``_is_linear_params``); each becomes ``{"qw", "scale"[, "b"]}`` —
    biases and every non-linear leaf (norms, convs, embeddings, deltas)
    pass through untouched. ``select`` filters by '/'-joined path (return
    False to keep a linear in fp).
    """
    if dtype not in INT_BITS:
        raise ValueError(f"unknown quantized dtype {dtype!r} "
                         f"(expected one of {sorted(INT_BITS)})")

    def visit(path, node):
        if select is not None and not select(path):
            return node
        q = quantize_weight(node["w"], dtype, dyadic=dyadic,
                            clip_ratio=clip_ratio)
        out = {k: v for k, v in node.items() if k != "w"}
        out.update(q)
        return out

    return map_param_dicts(params, _is_linear_params, visit)


def dequantize_tree(params: Any, dtype=jnp.float32) -> Any:
    """Inverse of :func:`quantize_tree` (up to quantization error): every
    {"qw","scale"} node becomes {"w"} again, in ``dtype``."""
    def visit(path, node):
        out = {k: v for k, v in node.items() if k not in ("qw", "scale")}
        out["w"] = dequantize_weight(node, dtype=dtype)
        return out
    return map_param_dicts(params, is_quantized, visit)


# ---------------------------------------------------------------------------
# footprint accounting
# ---------------------------------------------------------------------------


def tree_nbytes(tree: Any) -> int:
    return sum(l.nbytes for l in jax.tree_util.tree_leaves(tree))


def footprint_report(ref_params: Any, quant_params: Any) -> Dict[str, Any]:
    """Measured weight-footprint compression of a quantized tree.

    ``compression`` is quantized-leaf bytes (qw + scales) vs the same
    weights in the reference tree; ``total_compression`` counts the whole
    tree (embeddings, norms, biases included — the serving number).
    """
    ref_flat = dict(_flat_leaves(ref_params))
    q_flat = dict(_flat_leaves(quant_params))
    q_bytes = ref_bytes = 0
    for path, leaf in q_flat.items():
        # a scale counts only next to its qw — norm params ({"scale"})
        # are not quantized weights and must not skew the metric
        if path.endswith("/qw") or (path.endswith("/scale")
                                    and path[:-6] + "/qw" in q_flat):
            q_bytes += leaf.nbytes
    for path, leaf in ref_flat.items():
        if path.endswith("/w") and (path[:-2] + "/qw") in q_flat:
            ref_bytes += leaf.nbytes
    return {
        "ref_weight_bytes": int(ref_bytes),
        "quant_weight_bytes": int(q_bytes),
        "compression": float(ref_bytes / max(1, q_bytes)),
        "ref_total_bytes": int(tree_nbytes(ref_params)),
        "quant_total_bytes": int(tree_nbytes(quant_params)),
        "total_compression": float(tree_nbytes(ref_params)
                                   / max(1, tree_nbytes(quant_params))),
    }


def _flat_leaves(tree: Any):
    """('/'-joined path, leaf) pairs via jax's own path flattener — the
    same str-keyed convention checkpoint manifests use."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        yield "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path), leaf
