from .optimizers import Optimizer, adamw, adafactor, sgd
from .schedule import constant_schedule, warmup_cosine
from .grad_compress import (compress_state_init, compressed_gradients,
                            int8_compress, int8_decompress)
