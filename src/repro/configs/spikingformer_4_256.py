"""Spikingformer-4-256 — the paper's CIFAR-10 workload (§V-A):
4 encoder blocks, embedding dim 256, T_s=4, binary attention, pre-neuron
residuals. Trained with BrainCog in the paper; our spiking substrate
mirrors its LIF parameterization (core/spiking.py)."""
from repro.core.engine import EngineConfig
from repro.core.spiking import SpikingConfig
from .base import ModelConfig, VisionSpec

CONFIG = ModelConfig(
    name="spikingformer-4-256", family="spikingformer",
    num_layers=4, d_model=256, num_heads=8, num_kv_heads=8, head_dim=32,
    d_ff=1024, vocab_size=10,
    vision=VisionSpec(img_size=32, in_channels=3, sps_stages=2),
    spiking=SpikingConfig(time_steps=4),
    # dual-engine hot path: spike matmuls big enough to tile go through
    # the occupancy-skipping sparse kernel, and the SSA routes through
    # the binary engine (binary='auto' picks the fused MXU kernel once
    # the attention volume clears the same flop floor). The floor keeps
    # CPU smoke shapes on the plain XLA paths (engine dispatch is still
    # exercised — it just resolves dense/jnp there). sparse='auto' lets
    # eager (non-jit) sparse calls pick the gather-compacted decoded
    # datapath from the occupancy histogram when the spikes are ragged
    # rather than tile-coherent (DESIGN.md §9).
    engine=EngineConfig(mode="auto", sparse="auto", overlap="auto"),
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, head_dim=16, d_ff=128,
    vision=VisionSpec(img_size=16, in_channels=3, sps_stages=2),
    spiking=SpikingConfig(time_steps=2), dtype="float32", remat=False)
