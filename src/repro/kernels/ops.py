"""Jit'd public wrappers around the Pallas kernels.

``binary_attention`` / ``spike_attention`` carry a custom VJP: the forward
runs a Pallas kernel (the fused MXU pass, or the bit-packed AND-PopCount
score stage); the backward recomputes through the pure-jnp oracle with
surrogate gradients (standard recompute-in-bwd pattern — the L x L
attention matrix still never persists between fwd and bwd).

On non-TPU backends kernels run in ``interpret=True`` mode (bit-exact
Python execution of the kernel body) — that is how this CPU container
validates them; on TPU the same calls compile to Mosaic.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.bitpack import pack_bits
from repro.core.spiking import binarize
from . import ref
from .lif import lif_forward as _lif_pallas
from .popcount_attention import popcount_scores as _popcount_pallas
from .spike_attention import spike_attention as _attn_pallas
from .spike_matmul import spike_matmul as _matmul_pallas
from .spike_matmul import spike_matmul_batched as _matmul_batched_pallas


# ---------------------------------------------------------------------------
# binary attention (fwd: Pallas, bwd: surrogate-gradient recompute)
# ---------------------------------------------------------------------------
#
# The differentiable core works on the *folded* (BH, L, D) layout — the
# layout the binary-engine kernels consume. Dispatch callers (core/
# attention.py) fold their leading dims themselves; the model-layout
# (B', L, H, D) wrapper below keeps the historical entry point.

@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9, 10))
def _binary_attention(q, k, v, delta, alpha, scale, causal, binarize_scores,
                      use_popcount, block_q, block_k):
    if use_popcount:
        # faithful FPGA port: bit-pack the spikes, AND-PopCount the score
        # stage on the VPU, context stage as a jnp matmul on the exact
        # integer counts. Bit-identical to the MXU kernel: {0,1} dots in
        # fp32 ARE the popcounts, and the threshold compare is the same
        # expression.
        counts = _popcount_pallas(pack_bits(q), pack_bits(k),
                                  block_q=block_q, block_k=block_k)
        s = counts.astype(jnp.float32) * scale
        if binarize_scores:
            a = (s - delta >= 0).astype(jnp.float32)
        else:
            a = s
        if causal:
            lq, lk = a.shape[-2:]
            mask = jnp.tril(jnp.ones((lq, lk), bool))
            a = jnp.where(mask[None], a, 0.0)
        out = jnp.einsum("bqk,bkd->bqd", a, v.astype(jnp.float32),
                         preferred_element_type=jnp.float32)
        return out.astype(q.dtype)
    return _attn_pallas(q, k, v, scale=scale, delta=delta, causal=causal,
                        binarize_scores=binarize_scores,
                        block_q=block_q, block_k=block_k)


def _binary_fwd(q, k, v, delta, alpha, scale, causal, binarize_scores,
                use_popcount, block_q, block_k):
    out = _binary_attention(q, k, v, delta, alpha, scale, causal,
                            binarize_scores, use_popcount, block_q, block_k)
    return out, (q, k, v, delta, alpha)


def _jnp_folded(q, k, v, delta, alpha, scale, causal, binarize_scores):
    """Pure-jnp surrogate-gradient oracle on the folded (BH, L, D) layout."""
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32),
                   preferred_element_type=jnp.float32) * scale
    a = binarize(s, delta, alpha) if binarize_scores else s
    if causal:
        l = q.shape[1]
        mask = jnp.tril(jnp.ones((l, l), bool))
        a = jnp.where(mask[None], a, 0.0)
    out = jnp.einsum("bqk,bkd->bqd", a, v.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def _binary_bwd(scale, causal, binarize_scores, use_popcount, block_q,
                block_k, res, g):
    q, k, v, delta, alpha = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_, d_: _jnp_folded(q_, k_, v_, d_, alpha, scale,
                                           causal, binarize_scores),
        q, k, v, delta)
    dq, dk, dv, dd = vjp(g)
    return dq, dk, dv, dd, None


_binary_attention.defvjp(_binary_fwd, _binary_bwd)


def binary_attention(q, k, v, *, scale: float, delta, alpha: float = 4.0,
                     causal: bool = False, binarize_scores: bool = True,
                     use_popcount: bool = False,
                     block_q: int = 128, block_k: int = 128):
    """Folded-layout binary attention: q/k/v (BH, L, D) spike tensors.

    Forward runs the fused MXU Pallas kernel (``use_popcount=False``) or
    the bit-packed AND-PopCount score kernel (``use_popcount=True``);
    backward recomputes with surrogate gradients. This is the entry the
    binary-engine dispatch (core/engine.resolve_binary_mode) targets.
    """
    delta = jnp.asarray(delta, jnp.float32)
    return _binary_attention(q, k, v, delta, alpha, scale, causal,
                             binarize_scores, use_popcount,
                             block_q, block_k)


def spike_attention(q, k, v, *, scale: float, delta, alpha: float = 4.0,
                    causal: bool = False, binarize_scores: bool = True):
    """Model-layout fused binary attention: q/k/v (B', L, H, D)."""
    b, l, h, d = q.shape
    fold = lambda u: u.transpose(0, 2, 1, 3).reshape(b * h, l, d)
    out = binary_attention(fold(q), fold(k), fold(v), scale=scale,
                           delta=delta, alpha=alpha, causal=causal,
                           binarize_scores=binarize_scores)
    return out.reshape(b, h, l, d).transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# sparse spike matmul
# ---------------------------------------------------------------------------

def spike_matmul(s, w, *, bias=None, block_m: int = 128, block_n: int = 128,
                 block_k: int = 128):
    """y = s @ w (+ bias) with zero-block skipping. s: (M, K) spikes,
    w: (K, N). Non-divisible shapes are zero-padded internally."""
    return _matmul_pallas(s, w, bias=bias, block_m=block_m, block_n=block_n,
                          block_k=block_k)


def spike_matmul_batched(s, w, *, bias=None, block_m: int = 128,
                         block_n: int = 128, block_k: int = 128):
    """Batched y = s @ w (+ bias): s (T, B, ..., K) spikes folded into M.

    For a differentiable, config-driven entry use
    ``repro.core.engine.spike_linear`` — this wrapper is the raw fwd-only
    kernel call."""
    return _matmul_batched_pallas(s, w, bias=bias, block_m=block_m,
                                  block_n=block_n, block_k=block_k)


# ---------------------------------------------------------------------------
# LIF
# ---------------------------------------------------------------------------

def lif(currents, *, decay: float, v_th: float = 1.0,
        soft_reset: bool = False):
    """Fused LIF over (T, ..., D): folds middle dims into M."""
    t = currents.shape[0]
    d = currents.shape[-1]
    flat = currents.reshape(t, -1, d)
    out = _lif_pallas(flat, decay=decay, v_th=v_th, soft_reset=soft_reset,
                      block_m=min(256, flat.shape[1]),
                      block_d=min(512, d))
    return out.reshape(currents.shape)


# ---------------------------------------------------------------------------
# bit-packed popcount scores
# ---------------------------------------------------------------------------

def popcount_attention_scores(q_spikes, k_spikes):
    """q/k (BH, L, D) {0,1} -> int32 (BH, Lq, Lk) via pack + AND-popcount."""
    return _popcount_pallas(pack_bits(q_spikes), pack_bits(k_spikes))
