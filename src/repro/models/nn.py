"""Minimal functional NN layer library (pure JAX pytrees, no flax).

Conventions:
  * params are nested dicts of jnp arrays, created by ``*_init`` functions;
  * activations default to the model compute dtype (bf16), matmuls accumulate
    in fp32 via ``preferred_element_type`` then cast back;
  * attention is *chunked flash-style in pure jnp* (no L x L materialization)
    so 32k/500k shapes lower with bounded live memory; the Pallas kernels in
    ``repro.kernels`` replace the binary-attention inner loop on TPU.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain

# ---------------------------------------------------------------------------
# Initializers / basic layers
# ---------------------------------------------------------------------------


def normal(key, shape, std, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def linear_init(key, d_in: int, d_out: int, *, bias: bool = False,
                std: Optional[float] = None, dtype=jnp.bfloat16):
    std = (1.0 / math.sqrt(d_in)) if std is None else std
    p = {"w": normal(key, (d_in, d_out), std, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x, *, spikes: bool = False, counts: bool = False):
    # ``spikes=True`` marks the input as a {0,1} spike tensor (or, with
    # ``counts=True``, the sparse integer counts binary attention emits):
    # those call sites route through the dual-engine dispatch
    # (core/engine.py), which may run the occupancy-skipping sparse
    # kernel when an engine is installed. With no ambient engine this is
    # the plain dense path. Quantized param dicts ({'qw','scale'[,'b']},
    # repro.quant) dispatch transparently: spike inputs take the
    # int8-accumulating engine path (counts ride int32 lanes — int8
    # would wrap at 128), analog inputs the weight-only dequantizing
    # reference.
    if "qw" in p:
        from repro.core import engine as _engine  # lazy: no import cycle
        if spikes and _engine.get_engine() is not None:
            return _engine.spike_linear(p, x, counts=counts)
        return _engine.dense_quant_linear(p, x)
    if spikes:
        from repro.core import engine as _engine  # lazy: no import cycle
        if _engine.get_engine() is not None:
            return _engine.spike_linear(p, x)
    # emit in the activation dtype: the MXU accumulates fp32 internally,
    # and a bf16 result keeps every downstream collective (row-parallel
    # psum, FSDP gather of the transposed weight in bwd) in bf16 instead
    # of letting XLA hoist an f32 convert before them (§Perf F1: halved
    # the dominant all-reduces on all three hillclimb cells).
    y = jnp.dot(x, p["w"], preferred_element_type=x.dtype)
    if "b" in p:
        y = y + p["b"]
    return y


def rmsnorm_init(d: int, dtype=jnp.bfloat16):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.bfloat16):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def groupnorm(p, x, groups: int, eps: float = 1e-5):
    """GroupNorm over the last dim split into ``groups`` (RWKV head-norm)."""
    d = x.shape[-1]
    x32 = x.astype(jnp.float32).reshape(*x.shape[:-1], groups, d // groups)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = ((x32 - mu) * jax.lax.rsqrt(var + eps)).reshape(*x.shape[:-1], d)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def embedding_init(key, vocab: int, d: int, dtype=jnp.bfloat16):
    return {"table": normal(key, (vocab, d), 1.0 / math.sqrt(d), dtype)}


def embed(p, ids):
    return jnp.take(p["table"], ids, axis=0)


def unembed(p, x):
    return jnp.dot(x, p["table"].T.astype(x.dtype),
                   preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# BatchNorm with running stats (Spikingformer / CIFAR-Net use conv+BN)
# ---------------------------------------------------------------------------


def batchnorm_init(d: int, dtype=jnp.bfloat16):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def batchnorm_state_init(d: int):
    return {"mean": jnp.zeros((d,), jnp.float32),
            "var": jnp.ones((d,), jnp.float32)}


def batchnorm(p, state, x, *, train: bool, momentum: float = 0.9,
              eps: float = 1e-5):
    """BN over all leading axes; returns (y, new_state)."""
    x32 = x.astype(jnp.float32)
    if train:
        axes = tuple(range(x.ndim - 1))
        mu = jnp.mean(x32, axis=axes)
        var = jnp.var(x32, axis=axes)
        new_state = {"mean": momentum * state["mean"] + (1 - momentum) * mu,
                     "var": momentum * state["var"] + (1 - momentum) * var}
    else:
        mu, var = state["mean"], state["var"]
        new_state = state
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Activations / MLP
# ---------------------------------------------------------------------------


def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return partial(jax.nn.gelu, approximate=True)
    if name == "relu2":  # squared ReLU (Nemotron-4)
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name}")


def mlp_init(key, d_model: int, d_ff: int, *, gated: bool, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 3)
    p = {"up": linear_init(ks[0], d_model, d_ff, dtype=dtype),
         "down": linear_init(ks[1], d_ff, d_model, dtype=dtype)}
    if gated:
        p["gate"] = linear_init(ks[2], d_model, d_ff, dtype=dtype)
    return p


def mlp(p, x, act: str):
    h = linear(p["up"], x)
    if "gate" in p:
        h = activation(act)(linear(p["gate"], x)) * h
    else:
        h = activation(act)(h)
    h = constrain(h, "batch", "seq", "d_ff")
    return linear(p["down"], h)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Apply RoPE. x: (B, L, H, D), positions: (B, L) or (L,)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, L, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked (flash-style) attention — pure jnp, no L x L materialization
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _pad_to(x, size, axis):
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    window: Optional[int] = None,
                    q_offset=0,
                    kv_valid_len: Optional[jax.Array] = None,
                    scale: Optional[float] = None,
                    q_chunk: int = 1024,
                    kv_chunk: int = 2048) -> jax.Array:
    """Online-softmax attention with GQA broadcast.

    q: (B, Lq, H, D); k, v: (B, Lk, KH, D) with H % KH == 0.
    ``q_offset``: absolute position of q[0] (decode: cur_len - Lq).
    ``kv_valid_len``: mask out cache positions >= this (scalar or (B,)).
    ``window``: sliding-window attention width (None = full).
    """
    b, lq, h, d = q.shape
    _, lk, kh, _ = k.shape
    rep = h // kh
    scale = (1.0 / math.sqrt(d)) if scale is None else scale

    q_chunk = min(q_chunk, lq)
    kv_chunk = min(kv_chunk, lk)
    nq = -(-lq // q_chunk)
    nk = -(-lk // kv_chunk)

    qp = _pad_to(q, nq * q_chunk, 1).reshape(b, nq, q_chunk, h, d)
    kp = _pad_to(k, nk * kv_chunk, 1).reshape(b, nk, kv_chunk, kh, d)
    vp = _pad_to(v, nk * kv_chunk, 1).reshape(b, nk, kv_chunk, kh, d)
    # group query heads onto kv heads: (B, nq, qc, KH, rep, D)
    qp = qp.reshape(b, nq, q_chunk, kh, rep, d)

    q_pos_base = jnp.asarray(q_offset)
    kvl = None if kv_valid_len is None else jnp.asarray(kv_valid_len)

    # vmap over batch, scan over q chunks, inner scan over kv chunks
    def per_batch(q_b, k_b, v_b):
        def q_scan_body(_, inp):
            qi, q_blk = inp
            qpos = q_pos_base + qi * q_chunk + jnp.arange(q_chunk)

            def kv_body(carry, kv_inp):
                m, l, acc = carry
                ki, k_blk, v_blk = kv_inp
                kpos = ki * kv_chunk + jnp.arange(kv_chunk)
                s = jnp.einsum("qgrd,kgd->qgrk", q_blk, k_blk,
                               preferred_element_type=jnp.float32) * scale
                mask = jnp.ones((q_chunk, kv_chunk), bool)
                if causal:
                    mask &= kpos[None, :] <= qpos[:, None]
                if window is not None:
                    mask &= kpos[None, :] > qpos[:, None] - window
                mask &= (kpos < lk)[None, :]
                if kvl is not None:
                    mask &= (kpos < kvl)[None, :]
                s = jnp.where(mask[:, None, None, :], s, NEG_INF)
                m_new = jnp.maximum(m, s.max(-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l_new = l * corr + p.sum(-1)
                acc_new = acc * corr[..., None] + jnp.einsum(
                    "qgrk,kgd->qgrd", p.astype(v_blk.dtype), v_blk,
                    preferred_element_type=jnp.float32)
                return (m_new, l_new, acc_new), None

            m0 = jnp.full((q_chunk, kh, rep), NEG_INF, jnp.float32)
            l0 = jnp.zeros((q_chunk, kh, rep), jnp.float32)
            a0 = jnp.zeros((q_chunk, kh, rep, d), jnp.float32)
            (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0),
                                          (jnp.arange(nk), k_b, v_b))
            out = acc / jnp.maximum(l[..., None], 1e-20)
            return None, out.astype(q.dtype)

        _, outs = jax.lax.scan(q_scan_body, None,
                               (jnp.arange(nq), q_b))
        return outs  # (nq, qc, KH, rep, D)

    outs = jax.vmap(per_batch)(qp, kp, vp)
    out = outs.reshape(b, nq * q_chunk, h, d)[:, :lq]
    return out.astype(q.dtype)


def banded_flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           window: int,
                           scale: Optional[float] = None,
                           q_chunk: int = 512) -> jax.Array:
    """Sliding-window attention with *statically banded* compute.

    For each q chunk only the kv band ``[q_start - window, q_end]`` is
    touched (one dynamic_slice), so HLO FLOPs scale as O(L * window) instead
    of O(L^2) — this is what makes gemma3 local layers and SWA prefill at
    32k/500k roofline-sane. Causal by construction. Self-attention only
    (Lq == Lk, offset 0).
    """
    b, l, h, d = q.shape
    _, lk, kh, _ = k.shape
    assert l == lk, "banded attention is for self-attention prefill"
    rep = h // kh
    scale = (1.0 / math.sqrt(d)) if scale is None else scale
    q_chunk = min(q_chunk, l)
    nq = -(-l // q_chunk)
    lpad = nq * q_chunk
    band = min(lk, window + q_chunk)  # static band length

    qp = _pad_to(q, lpad, 1).reshape(b, nq, q_chunk, kh, rep, d)
    kp = _pad_to(k, lpad, 1)
    vp = _pad_to(v, lpad, 1)

    def per_batch(q_b, k_b, v_b):
        def q_body(_, inp):
            qi, q_blk = inp
            q_start = qi * q_chunk
            band_start = jnp.clip(q_start + q_chunk - band, 0, lpad - band)
            k_band = jax.lax.dynamic_slice_in_dim(k_b, band_start, band, 0)
            v_band = jax.lax.dynamic_slice_in_dim(v_b, band_start, band, 0)
            qpos = q_start + jnp.arange(q_chunk)
            kpos = band_start + jnp.arange(band)
            s = jnp.einsum("qgrd,kgd->qgrk", q_blk, k_band,
                           preferred_element_type=jnp.float32) * scale
            mask = (kpos[None, :] <= qpos[:, None])
            mask &= kpos[None, :] > qpos[:, None] - window
            mask &= (kpos < l)[None, :]
            s = jnp.where(mask[:, None, None, :], s, NEG_INF)
            m = s.max(-1, keepdims=True)
            p = jnp.exp(s - m)
            out = jnp.einsum("qgrk,kgd->qgrd", p.astype(v_band.dtype),
                             v_band, preferred_element_type=jnp.float32)
            out = out / jnp.maximum(p.sum(-1, keepdims=True), 1e-20)
            return None, out.astype(q.dtype)

        _, outs = jax.lax.scan(q_body, None,
                               (jnp.arange(nq),
                                q_b))
        return outs

    outs = jax.vmap(per_batch)(qp, kp, vp)
    return outs.reshape(b, lpad, h, d)[:, :l].astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, *,
                     entry_pos: jax.Array, cur_pos: jax.Array,
                     window: Optional[int] = None,
                     scale: Optional[float] = None) -> jax.Array:
    """Attention for a short query span against a (possibly rolling) KV
    cache — one decode token or a chunked-prefill bite.

    q: (B, Lq, H, D); k_cache/v_cache: (B, S, KH, D);
    entry_pos: (S,) or (B, S) absolute position of each cache entry (-1 =
    empty); cur_pos: absolute position of each query — scalar (all rows,
    Lq == 1), (B,) per-row first-query position, or (B, Lq) explicit.
    Causality comes entirely from the entry_pos <= query-position mask, so
    per-row positions give every batch row its own timeline.
    """
    b, lq, h, d = q.shape
    _, s_len, kh, _ = k_cache.shape
    rep = h // kh
    scale = (1.0 / math.sqrt(d)) if scale is None else scale
    if entry_pos.ndim == 1:
        entry_pos = entry_pos[None]
    qpos = jnp.asarray(cur_pos)
    if qpos.ndim == 0:
        qpos = qpos[None, None]
    elif qpos.ndim == 1:
        qpos = qpos[:, None] + jnp.arange(lq)
    qpos = jnp.broadcast_to(qpos, (b, lq))
    qf = q.reshape(b, lq, kh, rep, d).astype(jnp.float32)
    sc = jnp.einsum("bqgrd,bkgd->bqgrk", qf,
                    k_cache.astype(jnp.float32)) * scale
    valid = (entry_pos[:, None, :] >= 0) & \
        (entry_pos[:, None, :] <= qpos[:, :, None])
    if window is not None:
        valid &= entry_pos[:, None, :] > qpos[:, :, None] - window
    sc = jnp.where(valid[:, :, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bqgrk,bkgd->bqgrd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, lq, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Convolutions (Spikingformer SPS / CIFAR-Net)
# ---------------------------------------------------------------------------


def conv2d_init(key, c_in: int, c_out: int, ksize: int = 3,
                dtype=jnp.bfloat16):
    std = 1.0 / math.sqrt(c_in * ksize * ksize)
    return {"w": normal(key, (ksize, ksize, c_in, c_out), std, dtype)}


def conv2d(p, x, stride: int = 1, padding: str = "SAME"):
    """x: (B, H, W, C) NHWC."""
    y = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), p["w"].astype(jnp.float32),
        window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y.astype(x.dtype)


def maxpool2(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def causal_depthwise_conv1d(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (B, L, C); w: (K, C) depthwise causal conv (mamba front conv)."""
    k = w.shape[0]
    xpad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):
        out = out + xpad[:, i:i + x.shape[1]].astype(jnp.float32) * \
            w[i].astype(jnp.float32)
    return out.astype(x.dtype)


def sinusoid_positions(length: int, d: int) -> jax.Array:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def binary_flash_attention(q, k, v, *, delta, alpha: float,
                           causal: bool = True,
                           window: Optional[int] = None,
                           q_offset=0,
                           kv_valid_len: Optional[jax.Array] = None,
                           scale: Optional[float] = None,
                           binarize_scores: bool = True,
                           q_chunk: int = 1024,
                           kv_chunk: int = 2048) -> jax.Array:
    """Chunked *binary* attention (no softmax => single exact pass).

    scores = (Q @ K^T) * scale; attn = 1[scores > delta]; out = attn @ V.
    This is the pure-jnp reference dataflow of the binary engine; the Pallas
    kernel (kernels/spike_attention) implements the same contract.
    """
    from repro.core.spiking import binarize
    b, lq, h, d = q.shape
    _, lk, kh, _ = k.shape
    rep = h // kh
    scale = (1.0 / math.sqrt(d)) if scale is None else scale
    q_chunk = min(q_chunk, lq)
    kv_chunk = min(kv_chunk, lk)
    nq = -(-lq // q_chunk)
    nk = -(-lk // kv_chunk)

    qp = _pad_to(q, nq * q_chunk, 1).reshape(b, nq, q_chunk, kh, rep, d)
    kp = _pad_to(k, nk * kv_chunk, 1).reshape(b, nk, kv_chunk, kh, d)
    vp = _pad_to(v, nk * kv_chunk, 1).reshape(b, nk, kv_chunk, kh, d)
    kvl = None if kv_valid_len is None else jnp.asarray(kv_valid_len)
    q_pos_base = jnp.asarray(q_offset)

    def per_batch(q_b, k_b, v_b):
        def q_body(_, inp):
            qi, q_blk = inp
            qpos = q_pos_base + qi * q_chunk + jnp.arange(q_chunk)

            def kv_body(acc, kv_inp):
                ki, k_blk, v_blk = kv_inp
                kpos = ki * kv_chunk + jnp.arange(kv_chunk)
                s = jnp.einsum("qgrd,kgd->qgrk", q_blk, k_blk,
                               preferred_element_type=jnp.float32) * scale
                if binarize_scores:
                    a = binarize(s, jnp.asarray(delta, jnp.float32), alpha)
                else:
                    a = s
                mask = jnp.ones((q_chunk, kv_chunk), bool)
                if causal:
                    mask &= kpos[None, :] <= qpos[:, None]
                if window is not None:
                    mask &= kpos[None, :] > qpos[:, None] - window
                mask &= (kpos < lk)[None, :]
                if kvl is not None:
                    mask &= (kpos < kvl)[None, :]
                a = jnp.where(mask[:, None, None, :], a, 0.0)
                acc = acc + jnp.einsum("qgrk,kgd->qgrd",
                                       a.astype(v_blk.dtype), v_blk,
                                       preferred_element_type=jnp.float32)
                return acc, None

            a0 = jnp.zeros((q_chunk, kh, rep, d), jnp.float32)
            acc, _ = jax.lax.scan(kv_body, a0, (jnp.arange(nk), k_b, v_b))
            return None, acc.astype(q.dtype)

        _, outs = jax.lax.scan(q_body, None, (jnp.arange(nq), q_b))
        return outs

    outs = jax.vmap(per_batch)(qp, kp, vp)
    out = outs.reshape(b, nq * q_chunk, h, d)[:, :lq]
    return out.astype(q.dtype)
