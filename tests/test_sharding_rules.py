"""Sharding-rule machinery: spec fitting, scheme variants, cache specs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.shapes import DECODE_32K, LONG_500K, TRAIN_4K
from repro.launch import steps as steps_lib
from repro.parallel import rules
from repro.parallel.sharding import (fit_spec_to_shape, logical_spec,
                                     param_specs, rules_for_mesh, use_rules)


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _spec_leaves(tree):
    return jax.tree_util.tree_leaves(
        tree, is_leaf=lambda x: isinstance(x, P))


def test_fit_spec_right_aligns_for_stacked_params(mesh):
    # scan-stacked (L, d_in, d_out) with a 2D rule
    s = fit_spec_to_shape(P("data", "model"), (32, 64, 128), mesh)
    assert tuple(s) == (None, "data", "model")


def test_fit_spec_drops_nondividing(mesh):
    big = jax.make_mesh((1,), ("model",))

    class FakeMesh:
        axis_names = ("model",)
        devices = np.empty((16,))
    s = fit_spec_to_shape(P("model"), (25,), FakeMesh())
    assert tuple(s) == ()or tuple(s) == (None,)


def test_logical_spec_keeps_positional_nones():
    rls = dict(batch="data", seq=None, embed=None)
    s = logical_spec(("batch", "seq", "embed"), rls)
    assert tuple(s)[0] == "data" and len(s) == 3


def test_dense_rules_cover_all_leaves(mesh):
    cfg = get_config("nemotron-4-15b", smoke=True)
    abstract = steps_lib.abstract_params(cfg)
    specs = rules.params_partition(cfg, abstract, mesh)
    assert jax.tree_util.tree_structure(specs, is_leaf=lambda x: isinstance(
        x, P)) is not None
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    named = [p for p, s in flat if "wq" in str(p)]
    assert named, "attention projections must be matched by rules"


def test_zero1_strips_fsdp_axis(mesh):
    cfg = get_config("nemotron-4-15b", smoke=True)
    fsdp = rules.rules_for(cfg, mesh, "fsdp")
    zero1 = rules.rules_for(cfg, mesh, "zero1")
    d_f = dict(fsdp)
    d_z = dict(zero1)
    assert d_f[r"mlp/(up|gate)/(w|b)"] == P("data", "model")
    assert d_z[r"mlp/(up|gate)/(w|b)"] == P(None, "model")


def test_kv_replication_rule_when_heads_dont_divide():
    class M16:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    cfg = get_config("kimi-k2-1t-a32b")  # kv=8 < 16
    r = rules.rules_for(cfg, M16())
    assert r[0] == (r"(wk|wv)/(w|b)", P("data", None))
    cfg2 = get_config("nemotron-4-15b")  # kv=8 < 16 too
    r2 = rules.rules_for(cfg2, M16())
    assert r2[0][1] == P("data", None)


def test_cache_partition_long_context_shards_seq(mesh):
    class M16:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
        devices = np.empty((16, 16))
    cfg = get_config("h2o-danube-3-4b")  # full config: window 4096
    cache = steps_lib.cache_struct(cfg, LONG_500K)
    # batch=1 < data=16 -> KV seq dim sharded over data
    specs = rules.cache_partition(cfg, LONG_500K, M16(), cache)
    k_spec = specs["layers"]["k"]
    assert "data" in str(k_spec)


def test_constrain_fits_batch_one():
    mesh = jax.make_mesh((1,), ("data",))
    with use_rules(rules_for_mesh(mesh)):
        from repro.parallel.sharding import constrain
        x = jnp.zeros((1, 8, 16))
        y = constrain(x, "batch", "seq", "embed")  # batch=1: no crash
        assert y.shape == x.shape


def test_batch_axes_decode_shapes():
    class M:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    assert rules.batch_axes(TRAIN_4K, M()) == ("data",)
    assert rules.batch_axes(DECODE_32K, M()) == ("data",)
    assert rules.batch_axes(LONG_500K, M()) == ()
