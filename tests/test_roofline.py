"""HLO cost parser: trip-count multiplication, flop counting vs analytic."""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))

from hlo_cost import HloCost  # noqa: E402


def _lower_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_parser_counts_scanned_dots_times_trip():
    """A scan of N matmuls must count N x the body flops (XLA's own
    cost_analysis counts the body once — the bug this parser fixes)."""
    n_layers, m = 8, 64
    ws = jax.ShapeDtypeStruct((n_layers, m, m), jnp.float32)
    x0 = jax.ShapeDtypeStruct((4, m), jnp.float32)

    def fn(ws, x):
        def body(x, w):
            return x @ w, None
        x, _ = jax.lax.scan(body, x, ws)
        return x.sum()

    txt = _lower_text(fn, ws, x0)
    hc = HloCost(txt)
    flops, _, _, _, _ = hc.cost()
    expect = 2 * 4 * m * m * n_layers
    assert 0.9 * expect <= flops <= 1.3 * expect, (flops, expect)


def test_parser_counts_plain_dot():
    a = jax.ShapeDtypeStruct((32, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 64), jnp.float32)
    txt = _lower_text(lambda a, b: a @ b, a, b)
    flops, _, hbm, _, _ = HloCost(txt).cost()
    assert flops == pytest.approx(2 * 32 * 128 * 64, rel=0.01)
    # hbm >= operands + output
    assert hbm >= 4 * (32 * 128 + 128 * 64 + 32 * 64)


def test_parser_nested_scan_multiplies():
    m = 16

    def fn(x):
        def outer(x, _):
            def inner(x, _):
                return x @ jnp.eye(m), None
            x, _ = jax.lax.scan(inner, x, None, length=3)
            return x, None
        x, _ = jax.lax.scan(outer, x, None, length=5)
        return x.sum()

    txt = _lower_text(fn, jax.ShapeDtypeStruct((m, m), jnp.float32))
    flops, _, _, _, _ = HloCost(txt).cost()
    expect = 2 * m ** 3 * 15
    assert 0.9 * expect <= flops <= 1.4 * expect


def test_workload_model_census():
    sys.path.insert(0, "benchmarks")
    from workload_model import model_flops, param_census
    c = param_census("deepseek-moe-16b")
    assert 14e9 < c["total"] < 20e9          # ~16.4B
    assert c["active"] < 0.35 * c["total"]   # fine-grained MoE
    mf = model_flops("deepseek-moe-16b", "train_4k")
    assert mf["model_flops_global"] > 0
    # 6ND with N_active ~2.6B, D ~1M tokens => ~1.6e16
    assert 5e15 < mf["model_flops_global"] < 5e16


@pytest.mark.skipif(not os.path.isdir("artifacts/dryrun"),
                    reason="dry-run artifacts not generated")
def test_roofline_table_reads_artifacts():
    import roofline
    rows = roofline.full_table()
    ok = [r for r in rows if r.get("status") == "ok"]
    assert len(ok) >= 30
    for r in ok:
        assert r["compute_s"] > 0 and r["memory_s"] > 0
        assert r["dominant"] in ("compute", "memory", "collective")
        assert 0 <= r["roofline_frac"] <= 1.5
