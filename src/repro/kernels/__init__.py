"""Pallas TPU kernels for FireFly-T's two compute hot-spots:

  spike_attention    — fused binary attention (binary engine, MXU form)
  spike_matmul       — block-sparse spike x weight matmul (sparse engine,
                       tile datapath: whole-tile occupancy skip)
  spike_decode       — gather-compacted spike matmul (sparse engine,
                       decoded datapath: cumsum prefix-compaction +
                       pow2 occupancy-bucket load balancing)
  lif                — fused LIF membrane scan (neuronal dynamics module)
  popcount_attention — bit-packed AND-PopCount scores (faithful FPGA port,
                       kept for comparison; the MXU form wins on TPU)

Each kernel: pl.pallas_call + explicit BlockSpec VMEM tiling; ``ops.py``
jit'd wrappers; ``ref.py`` pure-jnp oracles (tests sweep shapes/dtypes).
"""
from . import ops, ref
