"""Hymba-style hybrid: parallel attention + mamba heads per block
(arXiv:2411.13676), hymba-1.5b.

Each block runs GQA attention and a selective-SSM branch *in parallel* on
the same normed input; outputs are per-channel re-normalized and averaged
with learned scale vectors, then a gated MLP follows. Meta-tokens are
omitted (noted in DESIGN.md §5). 25 heads is not divisible by the 16-way
model axis ⇒ heads stay replicated and TP shards d_ff / d_inner (sharding
rules in parallel/sharding.py).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel.sharding import constrain
from . import nn, ssm
from .transformer import _project_qkv, _attend_full_seq


def _layer_init(key, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 7)
    p = {
        "ln1": nn.rmsnorm_init(cfg.d_model, dt),
        "wq": nn.linear_init(ks[0], cfg.d_model, cfg.q_dim, dtype=dt),
        "wk": nn.linear_init(ks[1], cfg.d_model, cfg.kv_dim, dtype=dt),
        "wv": nn.linear_init(ks[2], cfg.d_model, cfg.kv_dim, dtype=dt),
        "wo": nn.linear_init(ks[3], cfg.q_dim, cfg.d_model,
                             std=1.0 / math.sqrt(cfg.q_dim * 2 * cfg.num_layers),
                             dtype=dt),
        "mamba": ssm.ssm_init(ks[4], cfg),
        "norm_attn": nn.rmsnorm_init(cfg.d_model, dt),
        "norm_mamba": nn.rmsnorm_init(cfg.d_model, dt),
        "ln2": nn.rmsnorm_init(cfg.d_model, dt),
        "mlp": nn.mlp_init(ks[5], cfg.d_model, cfg.d_ff, gated=cfg.gated,
                           dtype=dt),
    }
    if cfg.spiking is not None:
        p["delta"] = jnp.asarray(cfg.spiking.attn_threshold_init, jnp.float32)
    return p


def init(cfg: ModelConfig, key) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.dtype)
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    keys = jax.random.split(k_layers, cfg.num_layers)
    return {
        "embed": nn.embedding_init(k_embed, cfg.vocab_size, cfg.d_model, dt),
        "layers": jax.vmap(lambda k: _layer_init(k, cfg))(keys),
        "final_norm": nn.rmsnorm_init(cfg.d_model, dt),
        "lm_head": nn.linear_init(k_head, cfg.d_model, cfg.vocab_size,
                                  dtype=dt),
    }


def _layer(p, cfg: ModelConfig, x, positions, train: bool):
    h = nn.rmsnorm(p["ln1"], x, cfg.norm_eps)
    q, k, v = _project_qkv(p, cfg, h, positions, repeat_kv=True)
    kind = "window" if cfg.attn_type == "swa" else "full"
    attn = _attend_full_seq(cfg, kind, q, k, v,
                            delta=p.get("delta"))
    attn = nn.linear(p["wo"], attn.reshape(*x.shape[:-1], cfg.q_dim))
    m_out, _, _ = ssm.ssm_forward(p["mamba"], h, cfg)
    fused = 0.5 * (nn.rmsnorm(p["norm_attn"], attn, cfg.norm_eps) +
                   nn.rmsnorm(p["norm_mamba"], m_out, cfg.norm_eps))
    x = x + fused
    h2 = nn.rmsnorm(p["ln2"], x, cfg.norm_eps)
    x = x + nn.mlp(p["mlp"], h2, cfg.act)
    return constrain(x, "batch", "seq", "embed")


def forward(params, cfg: ModelConfig, batch, *, train: bool = False,
            inputs_embeds: Optional[jax.Array] = None):
    tokens = batch["tokens"]
    x = nn.embed(params["embed"], tokens) if inputs_embeds is None \
        else inputs_embeds
    x = constrain(x, "batch", "seq", "embed")
    positions = jnp.arange(x.shape[-2])

    layer_fn = _layer
    if cfg.remat and train:
        layer_fn = jax.checkpoint(_layer, static_argnums=(1, 4),
                                  policy=jax.checkpoint_policies.nothing_saveable)

    def body(x, lp):
        return layer_fn(lp, cfg, x, positions, train), None
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = nn.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = nn.linear(params["lm_head"], x).astype(jnp.float32)
    return constrain(logits, "batch", "seq", "vocab"), {}


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int,
               batch=None, params=None):
    dt = jnp.dtype(cfg.dtype)
    n = cfg.num_layers
    cache = {
        "k": jnp.zeros((n, batch_size, max_len, cfg.num_kv_heads,
                        cfg.head_dim), dt),
        "v": jnp.zeros((n, batch_size, max_len, cfg.num_kv_heads,
                        cfg.head_dim), dt),
        "pos": jnp.full((n, max_len), -1, jnp.int32),
    }
    cache.update(ssm.zero_states(cfg, n, batch_size))
    return cache


def decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    x = nn.embed(params["embed"], tokens)

    def body(x, inp):
        lp, c = inp
        h = nn.rmsnorm(lp["ln1"], x, cfg.norm_eps)
        q, k, v = _project_qkv(lp, cfg, h, jnp.full((1,), pos))
        s_len = c["k"].shape[1]
        slot = pos % s_len
        k_cache = jax.lax.dynamic_update_slice_in_dim(c["k"], k, slot, 1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(c["v"], v, slot, 1)
        entry_pos = jax.lax.dynamic_update_slice_in_dim(
            c["pos"], jnp.full((1,), pos, jnp.int32), slot, 0)
        window = cfg.window if cfg.attn_type == "swa" else None
        attn = nn.decode_attention(q, k_cache, v_cache, entry_pos=entry_pos,
                                   cur_pos=pos, window=window)
        attn = nn.linear(lp["wo"], attn.reshape(x.shape[0], 1, cfg.q_dim))
        m_out, h_ssm, conv = ssm.ssm_decode(lp["mamba"], h, cfg,
                                            c["ssm"], c["conv"])
        fused = 0.5 * (nn.rmsnorm(lp["norm_attn"], attn, cfg.norm_eps) +
                       nn.rmsnorm(lp["norm_mamba"], m_out, cfg.norm_eps))
        x = x + fused
        h2 = nn.rmsnorm(lp["ln2"], x, cfg.norm_eps)
        x = x + nn.mlp(lp["mlp"], h2, cfg.act)
        return x, {"k": k_cache, "v": v_cache, "pos": entry_pos,
                   "ssm": h_ssm, "conv": conv}

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    x = nn.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = nn.linear(params["lm_head"], x).astype(jnp.float32)
    return logits, new_cache
