"""Dual-engine sweep: both halves of the overlay.

Sparse engine (``rows``): dense XLA dot vs occupancy-skipping sparse
kernel. For each (sparsity, block, shape) point this times
``spike_linear``'s two dispatch targets on the same spike tensor and
records

  * dense_us / sparse_us — wall time per call (median of reps). On CPU
    the kernel runs in Pallas *interpret* mode, so the wall-clock ratio
    measures the lowered-lax emulation, not MXU tiles — the number that
    transfers to TPU is ``modeled_speedup``;
  * skip_fraction — fraction of (block_m x block_k) spike tiles whose
    occupancy bit is 0 (the sparse engine skips them: no weight fetch,
    no MACs);
  * modeled_speedup — 1 / (1 - skip_fraction), the MAC-count reduction
    the occupancy map guarantees on any backend.

Spikes are generated with *coherent* tile sparsity (Observation 1: spike
sparsity is uniform across the spatial-temporal grid, so channel blocks
go dark together): ``sparsity`` is the fraction of dead tiles; live
tiles fire at 25% density. That is the regime where whole-tile skips
pay; i.i.d. Bernoulli sparsity at the same rate almost never yields an
empty 128x128 tile and is reported by the bench as skip_fraction ~ 0.

Sparse datapaths (``sparse_path_rows``): tile vs decoded
(``EngineConfig.sparse``, DESIGN.md §9) on *fine-grained / ragged*
spike patterns — the regime where whole-tile skips never fire
(``skip_fraction ~ 0``) but per-row occupancy is low, so the
gather-compacted kernel's pow2 bucket schedule still cuts MACs. Each
row records both paths' wall time, the tile skip fraction, the decoded
schedule's MAC fraction (executed / total c_block-steps, scaled by the
compacted width), and the cross-validation of
``sim/balance_sim.predicted_schedule`` (Binomial occupancies from the
generator's density model) against the measured tensor schedule
(``kernels/spike_decode.build_schedule``) — ``sched_agreement`` is
predicted/measured executed steps. ``auto_choice`` is what
``sparse='auto'`` would pick from the concrete histogram.

Binary engine (``attention_rows``): the three SSA execution targets of
``core.engine.resolve_binary_mode`` — pure jnp, the fused MXU Pallas
kernel, the bit-packed popcount port — swept over L x d_head x causal on
identical spike tensors. All three are bit-identical (pinned by
tests/test_binary_engine.py); the sweep quantifies the *speed* gap the
dispatch rules encode (DESIGN.md §3: MXU dominates popcount on TPU). On
CPU the kernels run in interpret mode, so kernel wall-clock measures the
lowered-lax emulation — jnp_us is the transferable baseline there.

The measured medians also feed the overlap model: ``derived
['measured_overlap']`` runs ``core.dual_engine.measured_schedule`` on
(sparse_us, mxu_us) — the Fig. 5 latency-hiding fraction from measured
engine timings instead of the analytic MAC model.

Output: ``artifacts/dual_engine_bench.json`` in the benchmark harness's
``{"rows": [...], "attention_rows": [...], "derived": {...}}`` format
(also wired into ``benchmarks/run.py``, which re-emits the same file).

Usage: PYTHONPATH=src python benchmarks/dual_engine_bench.py [--fast]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

SHAPES = [(256, 128, 256), (512, 256, 256), (1024, 256, 512)]  # (M, K, N)
BLOCKS = [64, 128]
SPARSITIES = [0.5, 0.75, 0.9]
REPS = 5

# binary-engine sweep: (BH, L, d_head); 100 is deliberately non-divisible
# by the 128 attention blocks (exercises the kernels' zero-padding)
ATTN_SHAPES = [(8, 64, 32), (8, 100, 64), (8, 256, 64)]
ATTN_CAUSAL = [False, True]
ATTN_DENSITY = 0.25


def coherent_spikes(key, m, k, block, sparsity, density=0.25):
    """{0,1} (M, K) with ``sparsity`` fraction of (block x block) dead
    tiles; live tiles fire i.i.d. at ``density``."""
    k1, k2 = jax.random.split(key)
    nm, nk = -(-m // block), -(-k // block)
    live = jax.random.uniform(k1, (nm, nk)) >= sparsity
    tile_mask = jnp.repeat(jnp.repeat(live, block, 0), block, 1)[:m, :k]
    fire = jax.random.uniform(k2, (m, k)) < density
    return (tile_mask & fire).astype(jnp.float32)


def ragged_spikes(key, m, k, lo, hi):
    """{0,1} (M, K) with per-row i.i.d. firing at a log-uniform density
    in [lo, hi] — ragged occupancy, no tile coherence (the FireFly-S
    fine-grained regime the tile skip can't touch). Returns (spikes,
    per-row densities) so the bench can feed the density model to
    ``sim/balance_sim.predicted_schedule``."""
    k1, k2 = jax.random.split(key)
    logd = jax.random.uniform(k1, (m,), minval=jnp.log(lo),
                              maxval=jnp.log(hi))
    dens = jnp.exp(logd)
    s = (jax.random.uniform(k2, (m, k)) < dens[:, None])
    return s.astype(jnp.float32), dens


def fine_spikes(key, m, k, density):
    """{0,1} (M, K) i.i.d. Bernoulli — uniform fine-grained firing."""
    s = (jax.random.uniform(key, (m, k)) < density).astype(jnp.float32)
    return s, jnp.full((m,), density)


# sparse-datapath sweep: (pattern name, generator kwargs); two ragged
# patterns plus the uniform fine-grained point, all tile-incoherent
SPARSE_PATTERNS = [
    ("fine_iid", lambda key, m, k: fine_spikes(key, m, k, 0.10)),
    ("ragged_mild", lambda key, m, k: ragged_spikes(key, m, k, 0.02, 0.3)),
    ("ragged_extreme", lambda key, m, k: ragged_spikes(key, m, k,
                                                       0.005, 0.6)),
]
SPARSE_PATH_SHAPES = [(512, 256, 256), (1024, 256, 512)]
SPARSE_PATH_BLOCK = 64  # block_m/block_n; block_k doubles as c_block


def _time(fn, *args) -> float:
    fn(*args).block_until_ready()           # compile + warm
    ts = []
    for _ in range(REPS):
        t0 = time.perf_counter()
        fn(*args).block_until_ready()
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2] * 1e6   # median, us


def attention_bench(fast: bool = False):
    """Binary-engine sweep: jnp vs MXU kernel vs popcount per SSA shape."""
    from repro.core import engine as E
    from repro.core.attention import spiking_attention
    from repro.core.spiking import SpikingConfig

    scfg = SpikingConfig()
    shapes = ATTN_SHAPES[:2] if fast else ATTN_SHAPES
    rows = []
    for bh, l, d in shapes:
        ks = jax.random.split(jax.random.PRNGKey(bh + l + d), 3)
        q, k, v = ((jax.random.uniform(kk, (bh, l, d)) < ATTN_DENSITY)
                   .astype(jnp.float32) for kk in ks)
        for causal in ATTN_CAUSAL:
            us = {}
            for mode in ("jnp", "mxu_kernel", "popcount"):
                eng = E.EngineConfig(binary=mode)

                def call(q, k, v, eng=eng, causal=causal):
                    return spiking_attention(q, k, v, scfg,
                                             delta_score=0.3,
                                             causal=causal, engine=eng)
                us[mode] = _time(jax.jit(call), q, k, v)
            rows.append({
                "bench": "attention", "shape": [bh, l, d],
                "causal": causal,
                "jnp_us": round(us["jnp"], 1),
                "mxu_us": round(us["mxu_kernel"], 1),
                "popcount_us": round(us["popcount"], 1),
                "mxu_vs_jnp": round(us["jnp"] / us["mxu_kernel"], 3),
                "popcount_vs_mxu": round(
                    us["popcount"] / us["mxu_kernel"], 3),
            })
    return rows


def sparse_path_bench(fast: bool = False):
    """Tile vs decoded datapath on fine-grained / ragged spike patterns,
    plus the sim-vs-measured bucket-schedule cross-validation."""
    import numpy as np

    from repro.core import engine as E
    from repro.kernels.spike_decode import build_schedule, choose_sparse_path
    from repro.kernels.spike_matmul import block_occupancy
    from repro.sim.balance_sim import predicted_schedule

    shapes = SPARSE_PATH_SHAPES[:1] if fast else SPARSE_PATH_SHAPES
    block = SPARSE_PATH_BLOCK
    rows = []
    for m, k, n in shapes:
        for pat_name, gen in SPARSE_PATTERNS:
            # deterministic across processes (str hash() is salted)
            key = jax.random.PRNGKey(m + k + n + sum(map(ord, pat_name)))
            kw, ks = jax.random.split(key)
            s, dens = gen(ks, m, k)
            w = jax.random.normal(kw, (k, n), jnp.float32)
            p = {"w": w}
            tile_eng = E.EngineConfig(mode="sparse", sparse="tile",
                                      block_m=block, block_n=block,
                                      block_k=block)
            dec_eng = tile_eng.replace(sparse="decoded")
            dense_us = _time(jax.jit(
                lambda s, p=p: E.spike_linear(p, s, engine=E.DENSE)), s)
            tile_us = _time(jax.jit(
                lambda s, p=p, e=tile_eng: E.spike_linear(p, s,
                                                          engine=e)), s)
            dec_us = _time(jax.jit(
                lambda s, p=p, e=dec_eng: E.spike_linear(p, s,
                                                         engine=e)), s)
            occ_tiles = block_occupancy(s, block, block)
            tile_skip = float(1.0 - occ_tiles.mean())
            occ_rows = (s != 0).sum(-1).astype(jnp.int32)
            meas = build_schedule(occ_rows, block, block, cap=k)
            dec_frac = float(meas["mac_fraction"]) * \
                meas["padded_cap"] / k
            pred = predicted_schedule(m, k, np.asarray(dens), block,
                                      block, np.random.default_rng(0))
            rows.append({
                "bench": "sparse_path", "pattern": pat_name,
                "shape": [m, k, n], "block": block,
                "measured_sparsity": float(1.0 - s.mean()),
                "dense_us": round(dense_us, 1),
                "tile_us": round(tile_us, 1),
                "decoded_us": round(dec_us, 1),
                "tile_skip_fraction": round(tile_skip, 4),
                "tile_modeled_speedup": round(
                    1.0 / max(1e-9, 1.0 - tile_skip), 3),
                "decoded_mac_fraction": round(dec_frac, 4),
                "decoded_mac_reduction": round(1.0 - dec_frac, 4),
                "decoded_modeled_speedup": round(
                    1.0 / max(1e-9, dec_frac), 3),
                "sched_measured_steps": int(meas["executed"]),
                "sched_predicted_steps": int(pred["executed"]),
                "sched_agreement": round(
                    int(pred["executed"]) / max(1, int(meas["executed"])),
                    3),
                "auto_choice": choose_sparse_path(s, block, block),
            })
    return rows


def bench(fast: bool = False):
    from repro.core import engine as E
    from repro.core.dual_engine import (measured_overlap_efficiency,
                                        measured_schedule)
    from repro.kernels.spike_matmul import block_occupancy

    shapes = SHAPES[:2] if fast else SHAPES
    rows = []
    for m, k, n in shapes:
        for block in BLOCKS:
            for sparsity in SPARSITIES:
                key = jax.random.PRNGKey(m + block + int(sparsity * 100))
                kw, ks = jax.random.split(key)
                s = coherent_spikes(ks, m, k, block, sparsity)
                w = jax.random.normal(kw, (k, n), jnp.float32)
                p = {"w": w}
                sparse_eng = E.EngineConfig(mode="sparse", block_m=block,
                                            block_n=block, block_k=block)
                dense_us = _time(jax.jit(
                    lambda s, p=p: E.spike_linear(p, s, engine=E.DENSE)), s)
                sparse_us = _time(jax.jit(
                    lambda s, p=p, e=sparse_eng: E.spike_linear(
                        p, s, engine=e)), s)
                occ = block_occupancy(s, min(block, m), min(block, k))
                skip = float(1.0 - occ.mean())
                tiles = occ.size  # MAC reduction is bounded by the grid
                rows.append({
                    "bench": "linear",
                    "shape": [m, k, n], "block": block,
                    "sparsity": sparsity,
                    "measured_sparsity": float(1.0 - s.mean()),
                    "dense_us": round(dense_us, 1),
                    "sparse_us": round(sparse_us, 1),
                    "wall_speedup": round(dense_us / sparse_us, 3),
                    "skip_fraction": round(skip, 4),
                    "modeled_speedup": round(
                        min(1.0 / max(1e-9, 1.0 - skip), float(tiles)), 3),
                })
    attn_rows = attention_bench(fast=fast)
    sp_rows = sparse_path_bench(fast=fast)
    med = lambda xs: sorted(xs)[len(xs) // 2]
    sparse_med = med([r["sparse_us"] for r in rows])
    mxu_med = med([r["mxu_us"] for r in attn_rows])
    _, _, overlapped, serial = measured_schedule(sparse_med, mxu_med)
    derived = {
        "backend": jax.default_backend(),
        "interpret_mode": jax.default_backend() != "tpu",
        "points": len(rows),
        "max_modeled_speedup": max(r["modeled_speedup"] for r in rows),
        "mean_skip_at_0.9": round(sum(
            r["skip_fraction"] for r in rows if r["sparsity"] == 0.9) /
            max(1, sum(1 for r in rows if r["sparsity"] == 0.9)), 4),
        "attention_points": len(attn_rows),
        "mxu_vs_jnp_median": med([r["mxu_vs_jnp"] for r in attn_rows]),
        "popcount_vs_mxu_median": med(
            [r["popcount_vs_mxu"] for r in attn_rows]),
        # tile-vs-decoded on fine-grained/ragged patterns (DESIGN.md §9):
        # the tile skip is ~0 there by construction, so the decoded MAC
        # reduction is the whole sparse-engine story in that regime
        "sparse_path_points": len(sp_rows),
        "decoded_max_modeled_speedup": max(
            r["decoded_modeled_speedup"] for r in sp_rows),
        "tile_skip_on_ragged_max": max(
            r["tile_skip_fraction"] for r in sp_rows),
        "decoded_auto_wins": sum(
            1 for r in sp_rows if r["auto_choice"] == "decoded"),
        "sched_agreement_median": med(
            [r["sched_agreement"] for r in sp_rows]),
        # Fig. 5 overlap model on measured engine medians (us events)
        "measured_overlap": {
            "sparse_op_us": round(sparse_med, 1),
            "binary_op_us": round(mxu_med, 1),
            "overlapped_us": round(overlapped, 1),
            "serial_us": round(serial, 1),
            "hidden_fraction": round(
                measured_overlap_efficiency(sparse_med, mxu_med), 4),
        },
    }
    return rows + attn_rows + sp_rows, derived


def to_blob(rows, derived):
    """Split the tagged row list into the artifact layout
    ({'rows': linear, 'attention_rows': attention, 'sparse_path_rows':
    tile-vs-decoded, 'derived': ...})."""
    return {"rows": [r for r in rows
                     if r.get("bench") not in ("attention", "sparse_path")],
            "attention_rows": [r for r in rows
                               if r.get("bench") == "attention"],
            "sparse_path_rows": [r for r in rows
                                 if r.get("bench") == "sparse_path"],
            "derived": derived}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--out", default="artifacts/dual_engine_bench.json")
    args = ap.parse_args()
    rows, derived = bench(fast=args.fast)
    blob = to_blob(rows, derived)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(blob, f, indent=1)
    print("shape,block,sparsity,dense_us,sparse_us,wall_speedup,"
          "skip_fraction,modeled_speedup")
    for r in blob["rows"]:
        print(f"{'x'.join(map(str, r['shape']))},{r['block']},"
              f"{r['sparsity']},{r['dense_us']},{r['sparse_us']},"
              f"{r['wall_speedup']},{r['skip_fraction']},"
              f"{r['modeled_speedup']}")
    print("shape,causal,jnp_us,mxu_us,popcount_us,mxu_vs_jnp,"
          "popcount_vs_mxu")
    for r in blob["attention_rows"]:
        print(f"{'x'.join(map(str, r['shape']))},{r['causal']},"
              f"{r['jnp_us']},{r['mxu_us']},{r['popcount_us']},"
              f"{r['mxu_vs_jnp']},{r['popcount_vs_mxu']}")
    print("pattern,shape,tile_skip,decoded_mac_reduction,"
          "decoded_modeled_speedup,sched_agreement,auto")
    for r in blob["sparse_path_rows"]:
        print(f"{r['pattern']},{'x'.join(map(str, r['shape']))},"
              f"{r['tile_skip_fraction']},{r['decoded_mac_reduction']},"
              f"{r['decoded_modeled_speedup']},{r['sched_agreement']},"
              f"{r['auto_choice']}")
    print(json.dumps(derived))


if __name__ == "__main__":
    main()
