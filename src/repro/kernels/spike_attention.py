"""Fused binary spiking attention — the binary engine's MXU adaptation.

FireFly-T's binary engine computes QK^T and (QK^T)V on 1-bit operands with
AND-PopCount systolic PEs, overlapping them behind the sparse engine. On
TPU the dot product of {0,1} vectors IS AND-PopCount, and the MXU is the
popcount engine: this kernel fuses

    scores = (Q @ K^T) * scale          (MXU)
    attn   = 1[scores > delta]          (VPU, learnable threshold Delta)
    out   += attn @ V                   (MXU)

into one pass over KV blocks. Because binary attention has **no softmax**
there is no running-max/renormalization state — the fusion is exact in a
single pass (simpler than FlashAttention), and the L x L attention matrix
never touches HBM. This is also the paper's "implicit dataflow
manipulation" analogue: V is consumed tile-by-tile through the BlockSpec
index map, no transposition buffer is materialized.

Layout: q, k, v are (B*H, L, D) tiles; grid is (BH, nQ, nK) with the KV
axis innermost so the fp32 accumulator lives in the output block across
the nK steps (revisited-output accumulation pattern).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.bitpack import pad_to_multiple


def _kernel(delta_ref, q_ref, k_ref, v_ref, o_ref, *, scale: float,
            causal: bool, binarize: bool, block_q: int, block_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    def compute():
        q = q_ref[0].astype(jnp.float32)                  # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        if binarize:
            # spike(s - delta): identical expression to core.spiking
            # .binarize so kernel and jnp engine modes agree to the bit,
            # ties included (s >= delta via the subtraction's sign).
            a = (s - delta_ref[0, 0] >= 0).astype(jnp.float32)
        else:
            a = s
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            a = jnp.where(kpos <= qpos, a, 0.0)
        v = v_ref[0].astype(jnp.float32)                  # (bk, d)
        o_ref[0] += jax.lax.dot_general(
            a, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    if causal:
        # whole KV block strictly above the diagonal -> skip (latency hiding
        # of the useless half, block-granular like the sparse engine's skip)
        @pl.when(ki * block_k <= qi * block_q + block_q - 1)
        def _():
            compute()
    else:
        compute()


def spike_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    scale: float, delta, causal: bool = False,
                    binarize_scores: bool = True,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None) -> jax.Array:
    """q, k, v: (BH, L, D) binary spike tensors. Returns (BH, L, D) fp32
    accumulated context, cast back to q.dtype.

    L that doesn't divide the blocks is zero-padded: padded KV rows carry
    ``v == 0`` so whatever their (possibly binarized-to-1) attention
    weight, they add exact fp32 zeros to the context; padded Q rows are
    sliced off. The causal mask uses absolute padded positions, which
    agree with the real positions on every surviving entry — so padding
    is invisible bit-for-bit, causal or not.
    """
    bh, l, d = q.shape
    block_q = min(block_q, l)
    block_k = min(block_k, l)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    delta_arr = jnp.asarray(delta, jnp.float32).reshape(1, 1)

    qp = pad_to_multiple(q, 1, block_q)
    kp = pad_to_multiple(k, 1, block_k)
    vp = pad_to_multiple(v, 1, block_k)
    lq, lk = qp.shape[1], kp.shape[1]

    grid = (bh, lq // block_q, lk // block_k)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          binarize=binarize_scores,
                          block_q=block_q, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, qi, ki: (0, 0)),
            pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, lq, d), jnp.float32),
        interpret=interpret,
    )(delta_arr, qp, kp, vp)
    return out[:, :l].astype(q.dtype)
