"""Pure-jnp oracles for every Pallas kernel (the "ref.py" contract).

These define bit-exact semantics the kernels must match (tests sweep shapes
and dtypes against them with assert_allclose).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def spike_attention_ref(q, k, v, *, scale: float, delta, causal: bool,
                        binarize_scores: bool = True):
    """Fused binary attention oracle.

    q, k, v: (B, H, L, D) spike tensors ({0,1} values, float dtype).
    scores = (q @ k^T) * scale; attn = spike(scores - delta); out = attn @ v.
    No softmax (spiking attention, paper Eq. 2 + binary attention [17]).
    The threshold compare is ``(s - delta) >= 0`` — the exact expression
    of ``core.spiking.binarize`` — so all engine modes agree on ties.
    """
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if binarize_scores:
        a = (s - delta >= 0).astype(jnp.float32)
    else:
        a = s
    if causal:
        l = q.shape[2]
        mask = jnp.tril(jnp.ones((l, l), bool))
        a = jnp.where(mask[None, None], a, 0.0)
    out = jnp.einsum("bhqk,bhkd->bhqd", a, v.astype(jnp.float32))
    return out.astype(q.dtype)


def spike_matmul_ref(s, w):
    """Sparse-engine oracle: y = s @ w with s a {0,1} spike matrix.

    s: (M, K) spikes; w: (K, N) weights. fp32 accumulation.
    """
    return jnp.dot(s.astype(jnp.float32),
                   w.astype(jnp.float32)).astype(w.dtype)


def lif_ref(currents, *, decay: float, v_th: float, soft_reset: bool):
    """LIF oracle over leading time axis. currents: (T, ...) -> spikes."""
    def step(u, x):
        u = decay * u + x.astype(jnp.float32)
        s = (u >= v_th).astype(jnp.float32)
        u = u - s * v_th if soft_reset else u * (1.0 - s)
        return u, s
    u0 = jnp.zeros(currents.shape[1:], jnp.float32)
    _, spikes = jax.lax.scan(step, u0, currents)
    return spikes.astype(currents.dtype)


def popcount_scores_ref(q_packed, k_packed):
    """AND-PopCount oracle on bit-packed spikes.

    q_packed: (B, H, Lq, W) uint32; k_packed: (B, H, Lk, W) uint32.
    Returns (B, H, Lq, Lk) int32 overlap counts.
    """
    anded = q_packed[..., :, None, :] & k_packed[..., None, :, :]
    return jax.lax.population_count(anded).sum(axis=-1).astype(jnp.int32)
