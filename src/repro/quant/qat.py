"""Quantization-aware training: fake-quant with a straight-through VJP.

The serving datapath rounds weights to int8/int4 codes; QAT makes the
training loss see that rounding so the master weights settle where the
quantized model is accurate. ``fake_quant`` runs the *identical*
quantize→dequantize as ``repro.quant.quantize`` (same per-output-channel
symmetric scales, same round-to-nearest), entirely in fp32, and its VJP
is the straight-through estimator: ``round`` has zero gradient almost
everywhere, so the cotangent passes through unchanged and the optimizer
keeps moving the fp32 masters. Because the scale itself is max-derived
(no clipping at clip_ratio 1.0), no gradient masking is needed — every
weight stays inside the representable range by construction.

Plug into training via ``build_train_step(cfg, opt, qat='int8')``
(launch/steps.py) or ``launch/train.py --qat int8|int4``: the loss
closure fake-quantizes the param tree before the forward, grads flow to
the fp32 masters, and a post-training ``quantize_tree`` of the masters
produces exactly the weights the loss was trained against (same
quantizer ⇒ zero train/serve mismatch).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from .quantize import (INT_BITS, _is_linear_params, dequantize_values,
                       map_param_dicts, quantize_values, symmetric_scale)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def fake_quant(w: jax.Array, bits: int) -> jax.Array:
    """Per-output-channel symmetric quantize→dequantize in fp32 (the
    serving rounding made visible to the loss); identity VJP (STE)."""
    scale = symmetric_scale(w, bits, axis=-2)
    q = quantize_values(w, scale[..., None, :], bits)
    return dequantize_values(q, scale[..., None, :], w.dtype)


def _fake_quant_fwd(w, bits):
    return fake_quant(w, bits), None


def _fake_quant_bwd(bits, _res, g):
    return (g,)                                    # straight-through


fake_quant.defvjp(_fake_quant_fwd, _fake_quant_bwd)


def fake_quant_tree(params: Any, dtype: str = "int8") -> Any:
    """Fake-quantize every eligible linear weight in a param tree (same
    eligibility as ``quantize_tree``: 2-D / scan-stacked 3-D "w" dicts;
    biases, norms, convs, embeddings untouched). Differentiable — grads
    reach the fp32 masters through the STE."""
    bits = INT_BITS[dtype]
    return map_param_dicts(
        params, _is_linear_params,
        lambda path, node: {k: (fake_quant(v, bits) if k == "w" else v)
                            for k, v in node.items()})
