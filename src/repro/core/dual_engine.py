"""Dual-engine latency-hiding pipeline schedule (paper Section III-C,
Eq. 3/4) — analytic model *and* measurement consumer.

FireFly-T overlaps the sparse engine (Q/K/V projections) with the binary
engine (QK^T, QK^T V) across attention heads. This module holds the
discrete-event model of that schedule (Fig. 5) and, since the fused
dual-engine kernel landed (``kernels/fused_ssa.py``), the consumer that
turns the kernel's *measured* per-phase executed-step counts into a
hidden-fraction / utilization report (:func:`fused_step_metrics`). It is
used by:

* ``benchmarks/paper_figures.py``        — the Fig. 5 spatial-temporal
  overlap diagram (``pipeline_schedule``),
* ``benchmarks/dual_engine_bench.py``    — the measured-overlap rows
  (``measured_schedule`` on wall-clock medians; ``fused_step_metrics``
  on the fused kernel's step counts),
* ``examples/dual_engine_walkthrough.py``— the Eq. 4 engine-sizing rule
  (``required_binary_parallelism``) used to pick ``P_B*`` for a network.

On TPU the same overlap re-appears as HBM-prefetch ∥ MXU pipelining inside
the fused attention kernel and as compute/collective overlap at the
distribution layer (see DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence, Tuple, Union


@dataclasses.dataclass(frozen=True)
class EngineParallelism:
    """Hardware parallelism knobs (Table II)."""
    P_Ts: int = 2
    P_Fx: int = 4
    P_Ci: int = 16
    P_Co: int = 64
    # binary engine systolic array + inner-product width
    P_Bm: int = 4
    P_Bn: int = 4
    P_Bk: int = 32

    @property
    def P_s(self) -> int:
        return self.P_Ts * self.P_Fx * self.P_Ci * self.P_Co

    @property
    def P_b(self) -> int:
        return self.P_Bm * self.P_Bn * self.P_Bk


@dataclasses.dataclass(frozen=True)
class AttentionWorkload:
    """Per-head attention workload (Eq. 3)."""
    T_s: int
    F_h: int
    F_w: int
    C_i: int          # embedding dim d
    P_Co: int         # output-channel tile == per-head dim in the schedule
    heads: int = 8

    @property
    def L(self) -> int:
        return self.F_h * self.F_w

    def W_s(self) -> int:
        """Sparse-engine work per head per projection (MACs)."""
        return self.T_s * self.L * self.C_i * self.P_Co

    def W_b(self) -> int:
        """Binary-engine work per head per attention matmul (MACs)."""
        return self.T_s * self.L * self.L * self.P_Co


def required_binary_parallelism(w: AttentionWorkload, p: EngineParallelism) -> float:
    """Eq. 4: P_b ~= 2/3 * (Fh*Fw / Ci) * P_s for balanced overlap."""
    return 2.0 / 3.0 * (w.L / w.C_i) * p.P_s


# Per-head timing inputs: a scalar (every op identical — the original
# two-scalar model), or a per-head sequence whose entries are scalars or
# (Q, K, V) triples (sparse) / (QK^T, QK^TV) pairs (binary).
PerHead = Union[float, Sequence]


def _sparse_triples(ts: PerHead, heads: int) -> List[Tuple[float, ...]]:
    if not isinstance(ts, Sequence):
        return [(float(ts),) * 3] * heads
    if len(ts) != heads:
        raise ValueError(f"per-head sparse timings: got {len(ts)} entries "
                         f"for {heads} heads")
    return [(float(e),) * 3 if not isinstance(e, Sequence)
            else tuple(float(x) for x in e) for e in ts]


def _binary_pairs(tb: PerHead, heads: int) -> List[Tuple[float, ...]]:
    if not isinstance(tb, Sequence):
        return [(float(tb),) * 2] * heads
    if len(tb) != heads:
        raise ValueError(f"per-head binary timings: got {len(tb)} entries "
                         f"for {heads} heads")
    return [(float(e),) * 2 if not isinstance(e, Sequence)
            else tuple(float(x) for x in e) for e in tb]


def _event_schedule(ts: PerHead, tb: PerHead, heads: int
                    ) -> Tuple[List[tuple], List[tuple], float, float]:
    """Core event loop shared by the analytic and measured schedules:
    the sparse engine serially computes Q_h, K_h, V_h per head (``ts``
    each); the binary engine computes ``QK^T_h`` once Q_h,K_h are done
    and ``QK^T V_h`` once V_h is done (``tb`` each). ``ts``/``tb`` are
    scalars or per-head sequences (see :data:`PerHead`); the scalar path
    is numerically pinned to the original two-scalar model."""
    trips = _sparse_triples(ts, heads)
    pairs = _binary_pairs(tb, heads)
    sparse_events, binary_events = [], []
    t_sparse = 0.0
    qk_done = {}
    v_done = {}
    for h in range(heads):
        for name, dt in zip(("Q", "K", "V"), trips[h]):
            sparse_events.append((f"{name}{h}", t_sparse, t_sparse + dt))
            t_sparse += dt
            if name == "K":
                qk_done[h] = t_sparse
            if name == "V":
                v_done[h] = t_sparse
    t_bin = 0.0
    for h in range(heads):
        t_qk, t_qkv = pairs[h]
        start = max(t_bin, qk_done[h])
        binary_events.append((f"QK^T {h}", start, start + t_qk))
        t_bin = start + t_qk
        start = max(t_bin, v_done[h])
        binary_events.append((f"QK^TV {h}", start, start + t_qkv))
        t_bin = start + t_qkv

    total_overlapped = max(t_sparse, t_bin if binary_events else 0.0)
    if not isinstance(tb, Sequence):
        # the original scalar expression, verbatim (float-op-for-float-op:
        # the scalar path is pinned numerically unchanged)
        total_serial = t_sparse + 2 * float(tb) * heads
    else:
        total_serial = t_sparse + sum(t_qk + t_qkv
                                      for t_qk, t_qkv in pairs)
    return sparse_events, binary_events, total_overlapped, total_serial


def pipeline_schedule(w: AttentionWorkload, p: EngineParallelism,
                      sparsity: float = 0.0
                      ) -> Tuple[List[tuple], List[tuple], int, int]:
    """Discrete-event schedule of the latency-hiding pipeline (Fig. 5).

    Op latencies come from the analytic MAC model (Eq. 3 work over
    Table II parallelism; sparse throughput scales with input density
    when skipping is on). Returns (sparse_events, binary_events,
    total_overlapped, total_serial); events are (name, start, end) in
    cycles.
    """
    ts = w.W_s() / (p.P_s / max(1e-9, 1.0 - sparsity))  # sparse op latency
    tb = w.W_b() / p.P_b                                # binary op latency
    se, be, overlapped, serial = _event_schedule(ts, tb, w.heads)
    return se, be, math.ceil(overlapped), math.ceil(serial)


def measured_schedule(sparse_op_us: PerHead, binary_op_us: PerHead,
                      heads: int = 8
                      ) -> Tuple[List[tuple], List[tuple], float, float]:
    """Fig. 5 schedule fed with *measured* engine timings instead of the
    analytic MAC model — e.g. the per-call medians
    ``benchmarks/dual_engine_bench.py`` writes to
    ``artifacts/dual_engine_bench.json`` (``sparse_us`` from the matmul
    sweep, ``mxu_us`` from the attention sweep). Each input is a scalar
    (all heads/ops identical) or a per-head sequence — entries scalars or
    (Q, K, V) triples / (QK^T, QK^TV) pairs, e.g. derived from the fused
    kernel's per-phase executed-step counts. Events are in the same unit
    as the inputs; returns (sparse_events, binary_events,
    total_overlapped, total_serial).
    """
    if not isinstance(sparse_op_us, Sequence):
        sparse_op_us = float(sparse_op_us)
    if not isinstance(binary_op_us, Sequence):
        binary_op_us = float(binary_op_us)
    return _event_schedule(sparse_op_us, binary_op_us, heads)


def measured_overlap_efficiency(sparse_op_us: PerHead,
                                binary_op_us: PerHead,
                                heads: int = 8) -> float:
    """Fraction of the serial dual-engine latency the overlap hides,
    from measured timings: 1 - overlapped/serial."""
    _, _, overlapped, serial = measured_schedule(sparse_op_us,
                                                 binary_op_us, heads)
    if serial <= 0:
        return 0.0
    return 1.0 - overlapped / serial


def schedule_metrics(sparse_op_us: PerHead, binary_op_us: PerHead,
                     heads: int = 8) -> Dict[str, float]:
    """Hidden fraction *and* per-engine utilization of the Fig. 5
    schedule: utilization is each engine's busy time over the overlapped
    makespan (1.0 = that engine never stalls; the paper sizes ``P_B*`` so
    both stay near 1 — Eq. 4)."""
    se, be, overlapped, serial = measured_schedule(sparse_op_us,
                                                   binary_op_us, heads)
    sparse_busy = sum(e - s for _, s, e in se)
    binary_busy = sum(e - s for _, s, e in be)
    return {
        "overlapped": overlapped,
        "serial": serial,
        "hidden_fraction": 0.0 if serial <= 0 else 1.0 - overlapped / serial,
        "sparse_util": 0.0 if overlapped <= 0 else sparse_busy / overlapped,
        "binary_util": 0.0 if overlapped <= 0 else binary_busy / overlapped,
    }


LAYER_PHASE_NAMES = ("q", "k", "v", "qkt", "qktv", "wo", "up", "down")


def _interval_overlap(binary_events: List[tuple],
                      sparse_events: List[tuple]) -> float:
    """Total binary busy time that lies under sparse busy time."""
    total = 0.0
    for _, b0, b1 in binary_events:
        for _, s0, s1 in sparse_events:
            lo, hi = max(b0, s0), min(b1, s1)
            if hi > lo:
                total += hi - lo
    return total


def layer_event_schedule(macs: Dict[str, List[float]], heads: int,
                         iters: int = 1
                         ) -> Tuple[List[tuple], List[tuple]]:
    """Discrete-event schedule of the *layer program* (the fused-layer
    grid of ``kernels/fused_layer.py``): the sparse engine walks the
    phases in the kernel's phase-major grid order (q, k, v over all
    heads, then wo, up, down), the binary engine runs qkt/qktv as their
    operands land, and ``wo`` of head h stalls on ``qktv`` of head h
    (the context dependency). ``macs[phase][h]`` is the executed-MAC
    duration of that (phase, head) work item.

    ``iters > 1`` models the pipeline grid's timestep wavefront: the
    per-phase work splits evenly over ``iters`` chained iterations, so
    iteration i+1's q/k/v tiles fill the sparse-engine stall windows
    and overlap iteration i's binary tail — the reason the pipeline
    mode's measured hidden fraction exceeds the fused grid's.

    Returns (sparse_events, binary_events) as (name, start, end) lists.
    """
    se: List[tuple] = []
    be: List[tuple] = []
    t_s = 0.0
    t_b = 0.0
    frac = 1.0 / iters
    for it in range(iters):
        k_done: Dict[int, float] = {}
        v_done: Dict[int, float] = {}
        ctx_done: Dict[int, float] = {}
        for ph in ("q", "k", "v"):
            for h in range(heads):
                dt = macs[ph][h] * frac
                se.append((f"{ph}{h}@{it}", t_s, t_s + dt))
                t_s += dt
                if ph == "k":
                    k_done[h] = t_s
                elif ph == "v":
                    v_done[h] = t_s
        for h in range(heads):
            dt = macs["qkt"][h] * frac
            start = max(t_b, k_done[h])
            be.append((f"qkt{h}@{it}", start, start + dt))
            t_b = start + dt
        for h in range(heads):
            dt = macs["qktv"][h] * frac
            start = max(t_b, v_done[h])
            be.append((f"qktv{h}@{it}", start, start + dt))
            t_b = start + dt
            ctx_done[h] = t_b
        for h in range(heads):
            dt = macs["wo"][h] * frac
            start = max(t_s, ctx_done[h])
            se.append((f"wo{h}@{it}", start, start + dt))
            t_s = start + dt
        for ph in ("up", "down"):
            for h in range(heads):
                dt = macs[ph][h] * frac
                se.append((f"{ph}{h}@{it}", t_s, t_s + dt))
                t_s += dt
    return se, be


def _layer_step_metrics(counts, *, seq, k_dim, head_dim, t_steps, batch,
                        d_model, d_ff, l_block, sparse, c_block,
                        pipeline) -> Dict[str, float]:
    """The occupancy-map consumer: per-(head, phase, L-block) executed
    sub-block counts from the fused-layer kernel -> executed-MAC phase
    durations -> layer event schedule -> *binary-hidden fraction* (the
    share of binary-engine busy time that runs under sparse-engine busy
    time). Unlike the SSA-only makespan ratio, this is the quantity the
    layer program actually improves: the MLP tail (wo/up/down) gives the
    sparse engine work to run *under* the binary tail, and the pipeline
    grid additionally folds the next timestep's q/k/v into the wo stall
    windows."""
    cnt = [[[int(c) for c in lbrow] for lbrow in row] for row in counts]
    heads = len(cnt)
    nlb = len(cnt[0][0])
    rows = [min(l_block, seq - lb * l_block) for lb in range(nlb)]
    ffc = d_ff // heads
    decoded = sparse == "decoded"
    proj_k = c_block if decoded else k_dim
    unit = {"q": proj_k * head_dim, "k": proj_k * head_dim,
            "v": proj_k * head_dim,
            "qkt": seq * head_dim, "qktv": seq * head_dim,
            "wo": head_dim * d_model, "up": d_model * ffc,
            "down": ffc * d_model}
    macs = {ph: [float(sum(cnt[h][p][lb] * rows[lb]
                           for lb in range(nlb)) * unit[ph])
                 for h in range(heads)]
            for p, ph in enumerate(LAYER_PHASE_NAMES)}
    iters = t_steps if pipeline else 1
    se, be = layer_event_schedule(macs, heads, iters)
    sparse_busy = sum(e - s for _, s, e in se)
    binary_busy = sum(e - s for _, s, e in be)
    makespan = max([e for _, _, e in se + be], default=0.0)
    hidden = _interval_overlap(be, se)
    qkt_ev = [ev for ev in be if ev[0].startswith("qkt") and
              not ev[0].startswith("qktv")]
    qktv_ev = [ev for ev in be if ev[0].startswith("qktv")]
    qkt_busy = sum(e - s for _, s, e in qkt_ev)
    qktv_busy = sum(e - s for _, s, e in qktv_ev)
    executed = {ph: sum(cnt[h][p][lb] for h in range(heads)
                        for lb in range(nlb))
                for p, ph in enumerate(LAYER_PHASE_NAMES)}
    per_block = t_steps * batch * heads * nlb
    possible = {ph: per_block for ph in LAYER_PHASE_NAMES}
    if decoded:
        nc = -(-k_dim // c_block)
        for ph in ("q", "k", "v"):
            possible[ph] = per_block * nc
    tot_exec = sum(executed.values())
    tot_poss = sum(possible.values())
    return {
        "heads": heads,
        "phases": len(LAYER_PHASE_NAMES),
        "l_blocks": nlb,
        "pipeline_iters": iters,
        "executed_steps": tot_exec,
        "possible_steps": tot_poss,
        "step_reduction": 0.0 if tot_poss == 0
        else 1.0 - tot_exec / tot_poss,
        "sparse_busy": sparse_busy,
        "binary_busy": binary_busy,
        "makespan": makespan,
        "sparse_util": 0.0 if makespan <= 0 else sparse_busy / makespan,
        "binary_util": 0.0 if makespan <= 0 else binary_busy / makespan,
        # the binary-hidden fraction: binary busy time overlapped by
        # sparse busy time, over binary busy time
        "hidden_fraction": 0.0 if binary_busy <= 0
        else hidden / binary_busy,
        "qkt_hidden_fraction": 0.0 if qkt_busy <= 0
        else _interval_overlap(qkt_ev, se) / qkt_busy,
        "qktv_hidden_fraction": 0.0 if qktv_busy <= 0
        else _interval_overlap(qktv_ev, se) / qktv_busy,
        **{f"executed_{ph}": executed[ph] for ph in LAYER_PHASE_NAMES},
    }


def fused_step_metrics(counts, *, seq: int, k_dim: int, head_dim: int,
                       t_steps: int, batch: int, d_model: int = None,
                       d_ff: int = None, l_block: int = None,
                       sparse: str = "tile", c_block: int = None,
                       pipeline: bool = False) -> Dict[str, float]:
    """Measured overlap report from the fused kernel's executed-step
    counts — either the SSA bundle's ``(H, 4)`` int32 counts
    (``kernels/fused_ssa.fused_ssa``: executed Q/K/V projection dots and
    attention dots per head) or the layer program's ``(H, 8, n_l_blocks)``
    occupancy map (``kernels/fused_layer.fused_layer``: executed
    sub-blocks per head, phase and L-block — dispatched on the counts'
    rank; the layer path needs ``d_model``/``d_ff``/``l_block`` and, for
    ``sparse='decoded'``, ``c_block``).

    This is the "measured, not modeled" hidden fraction: op durations in
    the Fig. 5 schedule are the *executed* MACs of each phase — a
    projection sub-step the kernel skipped (all-dark spike slab) simply
    isn't there — with exact per-dot weights (projection dot = L*K*hd
    MACs, attention dot = L*L*hd). Deterministic for a fixed input, so
    CI gates it (benchmarks/check_regression.py).
    """
    ndim = counts.ndim if hasattr(counts, "ndim") else \
        (3 if isinstance(counts[0][0], (list, tuple)) else 2)
    if ndim == 3:
        return _layer_step_metrics(
            counts, seq=seq, k_dim=k_dim, head_dim=head_dim,
            t_steps=t_steps, batch=batch, d_model=d_model, d_ff=d_ff,
            l_block=l_block, sparse=sparse, c_block=c_block,
            pipeline=pipeline)
    rows = [[int(c) for c in row] for row in counts]
    heads = len(rows)
    w_proj = seq * k_dim * head_dim          # MACs per executed proj dot
    w_attn = seq * seq * head_dim            # MACs per executed attn dot
    sparse = [(r[0] * w_proj, r[1] * w_proj, r[2] * w_proj) for r in rows]
    binary = [(r[3] // 2 * w_attn, (r[3] - r[3] // 2) * w_attn)
              for r in rows]
    m = schedule_metrics(sparse, binary, heads)
    exec_q = sum(r[0] for r in rows)
    exec_k = sum(r[1] for r in rows)
    exec_v = sum(r[2] for r in rows)
    exec_attn = sum(r[3] for r in rows)
    possible_proj = 3 * t_steps * batch * heads
    possible_attn = 2 * t_steps * batch * heads
    executed = exec_q + exec_k + exec_v + exec_attn
    possible = possible_proj + possible_attn
    m.update({
        "heads": heads,
        "executed_q": exec_q, "executed_k": exec_k, "executed_v": exec_v,
        "executed_attn": exec_attn,
        "possible_steps": possible,
        "executed_steps": executed,
        # sequential baseline executes every sub-step back-to-back; the
        # fused step both *skips* dark projection slabs and *hides*
        # binary work behind sparse work — this is the skip half:
        "step_reduction": 0.0 if possible == 0
        else 1.0 - executed / possible,
        "proj_skip_fraction": 0.0 if possible_proj == 0
        else 1.0 - (exec_q + exec_k + exec_v) / possible_proj,
    })
    return m


def pipeline_efficiency(w: AttentionWorkload, p: EngineParallelism,
                        sparsity: float = 0.0) -> float:
    """Fraction of attention latency hidden: 1 -> perfect (O(3TsLd^2))."""
    _, _, overlapped, serial = pipeline_schedule(w, p, sparsity)
    ideal = 3 * w.heads * (w.W_s() / (p.P_s / max(1e-9, 1.0 - sparsity)))
    if overlapped <= 0:
        return 1.0
    return min(1.0, ideal / overlapped)


def complexity_reduction(w: AttentionWorkload) -> Tuple[int, int]:
    """(serial, overlapped) op counts: O(3TsLd^2 + 2TsL^2 d) -> O(3TsLd^2).

    Uses d == heads * P_Co as the full embedding dim.
    """
    d = w.C_i
    serial = 3 * w.T_s * w.L * d * d + 2 * w.T_s * w.L * w.L * d
    overlapped = 3 * w.T_s * w.L * d * d
    return serial, overlapped
