"""Quickstart: train a tiny Spikingformer (the paper's workload family)
with binary attention + LIF dynamics on synthetic images, then run
inference and report spike sparsity — the quantity FireFly-T's sparse
engine exploits.

    PYTHONPATH=src python examples/quickstart.py [--steps N]
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import DataConfig, make_pipeline
from repro.launch.steps import build_train_step
from repro.models import registry
from repro.models.spikingformer import layer_sparsities
from repro.optim import adamw, warmup_cosine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60,
                    help="training steps (tests run a short smoke)")
    args = ap.parse_args()
    cfg = get_config("spikingformer-4-256", smoke=True)
    print(f"model: {cfg.name} (smoke) — {cfg.num_layers} blocks, "
          f"d={cfg.d_model}, T_s={cfg.spiking.time_steps}, "
          f"binary attention={cfg.spiking.binarize_scores}")

    params = registry.init(cfg, jax.random.PRNGKey(0))
    state = registry.init_state(cfg)
    opt = adamw(warmup_cosine(2e-3, 5, 60))
    opt_state = opt.init(params)
    data = make_pipeline(DataConfig(kind="images", global_batch=16,
                                    img_size=cfg.vision.img_size,
                                    num_classes=cfg.vocab_size))
    step_fn = jax.jit(build_train_step(cfg, opt))

    step = jnp.asarray(0)
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        params, opt_state, step, metrics, state = step_fn(
            params, opt_state, step, batch, state)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:3d}  loss {float(metrics['loss']):.4f}  "
                  f"fire-rate {float(metrics['fire_rate']):.3f}")

    batch = {k: jnp.asarray(v) for k, v in data.batch_at(999).items()}
    logits, _ = registry.forward(params, cfg, batch, train=False,
                                 state=state)
    acc = float((logits.argmax(-1) == batch["labels"]).mean())
    print(f"\nheld-out batch accuracy: {acc:.2f}")
    print("\nlayer spike sparsity (what the sparse engine exploits):")
    for name, s in layer_sparsities(params, cfg, batch, state):
        print(f"  {name:14s} {s:.3f}")


if __name__ == "__main__":
    main()
