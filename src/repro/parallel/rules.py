"""Per-family parameter / cache / batch sharding rules.

Production meshes: (data=16, model=16) and (pod=2, data=16, model=16).
Scheme (DESIGN.md §6): batch over ('pod','data'); FSDP over 'data'
(GSPMD all-gathers weights per layer inside the scan); TP over 'model'
(attention q/o + d_ff columns, vocab, experts, mamba d_inner, rwkv
channels). Dims that don't divide fall back to replicated automatically
(sharding.fit_spec_to_shape) — e.g. hymba's 25 heads, whisper's odd vocab.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple  # noqa: F401

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, RunShape
from .sharding import fit_spec_to_shape, param_specs

BATCH = ("pod", "data")
FSDP = "data"
TP = "model"

_DENSE = [
    (r"embed/table", P(TP, FSDP)),
    (r"lm_head/w", P(FSDP, TP)),
    (r"mm_projector/.*w", P(FSDP, TP)),
    (r"(wq|wk|wv)/(w|b)", P(FSDP, TP)),
    (r"wo/w", P(TP, FSDP)),
    (r"mlp/(up|gate)/(w|b)", P(FSDP, TP)),
    (r"mlp/down/w", P(TP, FSDP)),
    (r"(self_attn|cross_attn|attn)/(wq|wk|wv)/(w|b)", P(FSDP, TP)),
    (r"(self_attn|cross_attn|attn)/wo/w", P(TP, FSDP)),
    (r"pos_embed", P(None, FSDP)),
    # quantized linears (repro.quant): int8/packed-int4 codes shard like
    # their fp weights (int4 halves the K rows — non-dividing K falls
    # back per-dimension); per-output-channel scales shard with N
    (r"(wq|wk|wv)/qw", P(FSDP, TP)),
    (r"wo/qw", P(TP, FSDP)),
    (r"mlp/(up|gate)/qw", P(FSDP, TP)),
    (r"mlp/down/qw", P(TP, FSDP)),
    (r"lm_head/qw", P(FSDP, TP)),
    (r"(wq|wk|wv|up|gate|lm_head)/scale", P(TP)),
    (r"(wo|down)/scale", P(FSDP)),
]

_MOE = [
    (r"moe/router", P()),
    (r"moe/(up|gate)", P(TP, FSDP, None)),
    (r"moe/down", P(TP, None, FSDP)),
    (r"moe/shared/(up|gate)/w", P(FSDP, TP)),
    (r"moe/shared/down/w", P(TP, FSDP)),
] + _DENSE

_RWKV = [
    # gates (wg, cm.wr) multiply replicated values elementwise — sharding
    # their outputs forced (B,S,D) regathers (§Perf R2); keep replicated.
    (r"tm/wg/w", P(FSDP, None)),
    (r"cm/wr/w", P(FSDP, None)),
    (r"tm/(wr|wk|wv)/w", P(FSDP, TP)),
    (r"tm/wo/w", P(TP, FSDP)),
    (r"cm/wk/w", P(FSDP, TP)),
    (r"cm/wv/w", P(TP, FSDP)),
    (r"embed/table", P(TP, FSDP)),
    (r"lm_head/w", P(FSDP, TP)),
]

_HYBRID = [
    (r"mamba/in_proj/w", P(FSDP, TP)),
    (r"mamba/conv_w", P(None, TP)),
    (r"mamba/conv_b", P(TP)),
    (r"mamba/x_proj/w", P(TP, None)),
    (r"mamba/dt_proj/(w|b)", P(None, TP)),
    (r"mamba/A_log", P(TP, None)),
    (r"mamba/D", P(TP)),
    (r"mamba/out_proj/w", P(TP, FSDP)),
] + _DENSE

FAMILY_RULES: Dict[str, List[Tuple[str, P]]] = {
    "dense": _DENSE,
    "vlm": _DENSE,
    "encdec": _DENSE,
    "moe": _MOE,
    "rwkv": _RWKV,
    "hybrid": _HYBRID,
    "spikingformer": [],
    "cifarnet": [],
}


def rules_for(cfg: ModelConfig, mesh: Optional[Mesh] = None,
              scheme: str = "fsdp") -> List[Tuple[str, P]]:
    """Param-sharding rules for a family under a scheme.

    scheme='fsdp'  — weights sharded over ('data','model'): minimal memory,
                     per-layer all-gathers (ZeRO-3-like). Baseline.
    scheme='zero1' — weights resident (TP over 'model' only); optimizer
                     states stay FSDP-sharded (launch passes scheme='fsdp'
                     for the opt-state spec assignment). Eliminates the
                     per-layer weight gathers; requires params+grads to fit
                     (not kimi-k2 at 256 chips — see EXPERIMENTS §Perf).

    Refinement (both schemes): when the KV heads can't shard over the
    model axis (GQA kv < model size), wk/wv outputs are REPLICATED instead
    of column-sharded — the (B,S,KH,hd) reshape would otherwise split a
    head across shards and GSPMD inserts per-layer activation all-gathers
    (measured: 8 x f32[16,4096,1,112] gathers/layer on kimi-k2).
    """
    rules = list(FAMILY_RULES[cfg.family])
    model_size = mesh.shape.get(TP, 1) if mesh is not None else 16
    if cfg.family in ("dense", "moe", "vlm", "hybrid") and \
            cfg.num_kv_heads % model_size != 0:
        rules = [(r"(wk|wv)/(w|b)", P(FSDP, None)),
                 (r"(wk|wv)/qw", P(FSDP, None)),
                 (r"(wk|wv)/scale", P())] + rules
    if scheme == "zero1":
        def strip_fsdp(spec: P) -> P:
            parts = []
            for part in spec:
                if part == FSDP:
                    parts.append(None)
                elif isinstance(part, tuple):
                    kept = tuple(a for a in part if a != FSDP)
                    parts.append(kept if len(kept) > 1 else
                                 (kept[0] if kept else None))
                else:
                    parts.append(part)
            return P(*parts)
        rules = [(rx, strip_fsdp(spec)) for rx, spec in rules]
    return rules


def params_partition(cfg: ModelConfig, abstract_params, mesh: Mesh,
                     scheme: str = "fsdp"):
    """PartitionSpec tree for a (possibly abstract) param pytree."""
    return param_specs(abstract_params, rules_for(cfg, mesh, scheme),
                       default=P(), mesh=mesh)


def tree_shardings(spec_tree, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P))


def batch_axes(shape: RunShape, mesh: Mesh) -> Tuple[str, ...]:
    """DP axes for this run shape; decode batch=1 stays replicated."""
    axes, prod = [], 1
    for a in BATCH:
        if a in mesh.axis_names:
            n = mesh.shape[a]
            if shape.global_batch % (prod * n) == 0:
                axes.append(a)
                prod *= n
    return tuple(axes)


def dp_part(dp: Tuple[str, ...]):
    """Normalize a DP-axis tuple to a PartitionSpec entry: () -> None,
    one axis -> its name, several -> the tuple."""
    return dp if len(dp) > 1 else (dp[0] if dp else None)


def batch_partition(cfg: ModelConfig, shape: RunShape, mesh: Mesh,
                    batch_tree) -> Any:
    """Spec tree for a data batch (tokens / embeds / labels / images)."""
    dp = dp_part(batch_axes(shape, mesh))

    def assign(path, leaf):
        nd = len(leaf.shape)
        return P(*((dp,) + (None,) * (nd - 1))) if nd else P()

    return jax.tree_util.tree_map_with_path(assign, batch_tree)


_CACHE_RULES_BASE = [
    (r"(^|/)(k|v)$", P(None, BATCH, "__SEQ__", TP, None)),
    (r"cross_(k|v)$", P(None, BATCH, None, TP, None)),
    (r"(^|/)pos$", P()),
    (r"wkv$", P(None, BATCH, None, None, None)),
    (r"tm_prev$", P(None, BATCH, None)),
    (r"cm_prev$", P(None, BATCH, None)),
    (r"ssm$", P(None, BATCH, TP, None)),
    (r"conv$", P(None, BATCH, None, TP)),
]


def cache_partition(cfg: ModelConfig, shape: RunShape, mesh: Mesh,
                    abstract_cache) -> Any:
    """Spec tree for the decode cache. For long-context decode (batch too
    small to shard) the KV sequence dim is sharded over 'data' instead —
    sequence-parallel KV (DESIGN.md §6)."""
    dp = batch_axes(shape, mesh)
    seq_shard = None
    if shape.global_batch < mesh.shape.get("data", 1):
        seq_shard = FSDP  # long_500k: shard the 500k cache over 'data'

    def materialize(spec: P) -> P:
        parts = []
        for part in spec:
            if part == "__SEQ__":
                parts.append(seq_shard)
            elif part == BATCH:
                parts.append(dp if len(dp) > 1 else
                             (dp[0] if dp else None))
            else:
                parts.append(part)
        return P(*parts)

    rules = [(rx, materialize(spec)) for rx, spec in _CACHE_RULES_BASE]
    specs = param_specs(abstract_cache, rules, default=P(), mesh=mesh)

    # slotted-decode validity tags are (n_layers, B, s) — shard the slot
    # dim with the batch like k/v. Shape-gated (not a regex rule) because
    # legacy families still carry 2-d (n_layers, s) tags, and a
    # right-aligned spec would land the batch axes on n_layers.
    def fix_pos(path, leaf, spec):
        if getattr(path[-1], "key", None) == "pos" and \
                len(getattr(leaf, "shape", ())) == 3:
            return fit_spec_to_shape(P(None, dp_part(dp), None),
                                     leaf.shape, mesh)
        return spec

    return jax.tree_util.tree_map_with_path(fix_pos, abstract_cache, specs)
