"""Deterministic synthetic data pipelines.

Offline container => no real corpora; the pipelines generate *learnable*
synthetic data deterministically from (seed, step, shard) so that:
  * training loss demonstrably decreases (integration tests),
  * restarts resume bit-identically mid-stream (fault-tolerance tests),
  * multi-host sharding is just a shard index (each host computes only its
    slice — no host ever materializes the global batch).

SyntheticLM: a first-order Markov token stream (random but fixed transition
structure) — next-token entropy is well below uniform, so a model that
learns reduces loss fast. SyntheticImages: class-conditional blob images
for the spiking classifiers.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    kind: str                  # 'lm' | 'images'
    global_batch: int
    seq_len: int = 0
    vocab_size: int = 0
    img_size: int = 32
    channels: int = 3
    num_classes: int = 10
    seed: int = 1234
    shard_index: int = 0
    num_shards: int = 1

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.num_shards == 0
        return self.global_batch // self.num_shards


class SyntheticLM:
    """First-order Markov chain over a hashed transition table."""

    def __init__(self, cfg: DataConfig, branching: int = 8):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        self.next_tokens = rng.integers(0, v, size=(v, branching),
                                        dtype=np.int32)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, step, cfg.shard_index))
        b, s = cfg.local_batch, cfg.seq_len
        toks = np.empty((b, s), dtype=np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab_size, size=b)
        branch = rng.integers(0, self.next_tokens.shape[1], size=(b, s))
        for t in range(1, s):
            toks[:, t] = self.next_tokens[toks[:, t - 1], branch[:, t]]
        return {"tokens": toks}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class SyntheticImages:
    """Class-conditional Gaussian-blob images + labels."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        n, c = cfg.num_classes, cfg.channels
        self.prototypes = rng.uniform(
            0.2, 0.8, size=(n, cfg.img_size, cfg.img_size, c)).astype(
                np.float32)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step, cfg.shard_index))
        b = cfg.local_batch
        labels = rng.integers(0, cfg.num_classes, size=b)
        noise = rng.normal(0, 0.15, size=(b, cfg.img_size, cfg.img_size,
                                          cfg.channels)).astype(np.float32)
        images = np.clip(self.prototypes[labels] + noise, 0.0, 1.0)
        return {"images": images, "labels": labels.astype(np.int32)}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_pipeline(cfg: DataConfig):
    if cfg.kind == "lm":
        return SyntheticLM(cfg)
    if cfg.kind == "images":
        return SyntheticImages(cfg)
    raise ValueError(cfg.kind)
