"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps (interpret mode
on CPU; the same pallas_call compiles to Mosaic on TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bitpack import pack_bits
from repro.kernels import ops, ref
from repro.kernels.spike_attention import spike_attention as attn_raw
from repro.kernels.spike_matmul import spike_matmul as matmul_raw
from repro.kernels.lif import lif_forward


def _spikes(key, shape, p=0.25, dtype=jnp.float32):
    return (jax.random.uniform(key, shape) < p).astype(dtype)


@pytest.mark.parametrize("l,d,blk", [(64, 32, 32), (128, 64, 64),
                                     (256, 128, 128), (96, 48, 32)])
@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_spike_attention_sweep(l, d, blk, causal, dtype):
    if l % blk:
        pytest.skip("block must divide L")
    ks = jax.random.split(jax.random.PRNGKey(l + d), 3)
    q, k, v = (_spikes(kk, (4, l, d), dtype=dtype) for kk in ks)
    out = attn_raw(q, k, v, scale=1 / np.sqrt(d), delta=0.3, causal=causal,
                   block_q=blk, block_k=blk)
    want = ref.spike_attention_ref(q.reshape(4, 1, l, d),
                                   k.reshape(4, 1, l, d),
                                   v.reshape(4, 1, l, d),
                                   scale=1 / np.sqrt(d), delta=0.3,
                                   causal=causal).reshape(4, l, d)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-5, atol=1e-5)


def test_spike_attention_no_binarize_matches_raw_scores_times_v():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (_spikes(kk, (2, 64, 32)) for kk in ks)
    out = attn_raw(q, k, v, scale=0.5, delta=0.0, causal=False,
                   binarize_scores=False, block_q=32, block_k=32)
    want = ref.spike_attention_ref(q[:, None], k[:, None], v[:, None],
                                   scale=0.5, delta=0.0, causal=False,
                                   binarize_scores=False)[:, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5)


def test_spike_attention_ops_layout_and_grads():
    b, l, h, d = 2, 64, 3, 32
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q, k, v = (_spikes(kk, (b, l, h, d)) for kk in ks)
    out = ops.spike_attention(q, k, v, scale=1 / np.sqrt(d), delta=0.2,
                              causal=True)
    want = ref.spike_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), scale=1 / np.sqrt(d), delta=0.2,
        causal=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5)
    g = jax.grad(lambda q: ops.spike_attention(
        q, k, v, scale=1 / np.sqrt(d), delta=0.2, causal=True).sum())(q)
    assert np.isfinite(np.asarray(g)).all()
    assert float(jnp.abs(g).sum()) > 0


@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (128, 256, 192, 32, 64, 32), (64, 64, 64, 64, 64, 64),
    (256, 128, 128, 128, 128, 128), (96, 160, 64, 32, 32, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_spike_matmul_sweep(m, k, n, bm, bn, bk, dtype):
    key1, key2 = jax.random.split(jax.random.PRNGKey(m + n))
    s = _spikes(key1, (m, k))
    w = jax.random.normal(key2, (k, n), dtype)
    got = matmul_raw(s, w, block_m=bm, block_n=bn, block_k=bk)
    want = ref.spike_matmul_ref(s, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-5)


def test_spike_matmul_skips_zero_blocks_correctly():
    s = _spikes(jax.random.PRNGKey(0), (128, 256))
    s = s.at[:, 64:192].set(0.0)  # two zero K-stripes
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 64))
    got = ops.spike_matmul(s, w, block_m=64, block_n=64, block_k=64)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.spike_matmul_ref(s, w)),
                               rtol=1e-5, atol=1e-5)
    from repro.kernels.spike_matmul import block_occupancy
    occ = block_occupancy(s, 64, 64)
    assert not occ[:, 1].any() and not occ[:, 2].any()


@pytest.mark.parametrize("t,m,d", [(4, 64, 128), (2, 256, 512), (8, 32, 64)])
@pytest.mark.parametrize("soft", [False, True])
def test_lif_kernel_sweep(t, m, d, soft):
    x = jax.random.normal(jax.random.PRNGKey(t * d), (t, m, d)) * 2
    got = lif_forward(x, decay=0.5, v_th=1.0, soft_reset=soft,
                      block_m=min(64, m), block_d=min(128, d))
    want = ref.lif_ref(x, decay=0.5, v_th=1.0, soft_reset=soft)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_lif_ops_wrapper_arbitrary_dims():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 2, 8, 64))
    got = ops.lif(x, decay=0.5)
    want = ref.lif_ref(x.reshape(4, -1, 64), decay=0.5, v_th=1.0,
                       soft_reset=False).reshape(x.shape)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("l,d", [(64, 64), (128, 128), (64, 256)])
def test_popcount_scores_sweep(l, d):
    ks = jax.random.split(jax.random.PRNGKey(l), 2)
    q = _spikes(ks[0], (3, l, d))
    k = _spikes(ks[1], (3, l, d))
    got = ops.popcount_attention_scores(q, k)
    exact = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exact))
    want = ref.popcount_scores_ref(pack_bits(q), pack_bits(k))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
