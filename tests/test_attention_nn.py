"""Chunked flash / banded / binary attention vs naive references."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.spiking import binarize
from repro.models import nn


def naive(q, k, v, *, causal=True, window=None, q_offset=0, kvl=None,
          scale=None):
    b, lq, h, d = q.shape
    _, lk, kh, _ = k.shape
    rep = h // kh
    scale = 1 / np.sqrt(d) if scale is None else scale
    kk = jnp.repeat(k, rep, axis=2)
    vv = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) * scale
    qpos = q_offset + jnp.arange(lq)
    kpos = jnp.arange(lk)
    m = jnp.ones((lq, lk), bool)
    if causal:
        m &= kpos[None] <= qpos[:, None]
    if window:
        m &= kpos[None] > qpos[:, None] - window
    if kvl is not None:
        m &= (kpos < kvl)[None]
    s = jnp.where(m[None, None], s, -1e30)
    return jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vv)


def _qkv(lq=37, lk=53, h=8, kh=4, d=16, b=2, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (b, lq, h, d)),
            jax.random.normal(ks[1], (b, lk, kh, d)),
            jax.random.normal(ks[2], (b, lk, kh, d)))


@pytest.mark.parametrize("kwargs", [
    dict(causal=True), dict(causal=False), dict(causal=True, window=7),
    dict(causal=True, q_offset=16),
])
@pytest.mark.parametrize("chunks", [(16, 16), (8, 32), (64, 64)])
def test_flash_matches_naive(kwargs, chunks):
    q, k, v = _qkv()
    out = nn.flash_attention(q, k, v, q_chunk=chunks[0], kv_chunk=chunks[1],
                             **kwargs)
    want = naive(q, k, v, **kwargs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=1e-4)


def test_flash_kv_valid_len():
    q, k, v = _qkv()
    out = nn.flash_attention(q, k, v, q_chunk=16, kv_chunk=16,
                             q_offset=16, kv_valid_len=40)
    want = naive(q, k, v, q_offset=16, kvl=40)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("window,l", [(8, 64), (16, 128), (64, 96)])
def test_banded_matches_flash_window(window, l):
    q, k, v = _qkv(lq=l, lk=l, seed=3)
    got = nn.banded_flash_attention(q, k, v, window=window, q_chunk=16)
    want = naive(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-4)


def test_binary_flash_matches_dense_binary():
    b, l, h, kh, d = 2, 48, 8, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = (jax.random.uniform(ks[0], (b, l, h, d)) > 0.75).astype(jnp.float32)
    k = (jax.random.uniform(ks[1], (b, l, kh, d)) > 0.75).astype(jnp.float32)
    v = (jax.random.uniform(ks[2], (b, l, kh, d)) > 0.75).astype(jnp.float32)
    got = nn.binary_flash_attention(q, k, v, delta=0.3, alpha=4.0,
                                    q_chunk=16, kv_chunk=16)
    kk = jnp.repeat(k, 2, 2)
    vv = jnp.repeat(v, 2, 2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / np.sqrt(d)
    a = binarize(s, 0.3, 4.0)
    mask = jnp.tril(jnp.ones((l, l), bool))
    a = jnp.where(mask[None, None], a, 0.0)
    want = jnp.einsum("bhqk,bkhd->bqhd", a, vv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_decode_attention_matches_naive_row():
    b, h, kh, d, s_len = 2, 8, 4, 16, 32
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (b, 1, h, d))
    kc = jax.random.normal(ks[1], (b, s_len, kh, d))
    vc = jax.random.normal(ks[2], (b, s_len, kh, d))
    entry_pos = jnp.arange(s_len)
    out = nn.decode_attention(q, kc, vc, entry_pos=entry_pos,
                              cur_pos=jnp.asarray(20), window=8)
    want = naive(q, kc, vc, causal=True, window=8, q_offset=20)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=1e-4)


def test_flash_fp32_accumulation_stability():
    # long context with bf16 inputs should not blow up
    q, k, v = _qkv(lq=16, lk=2048, h=2, kh=2, d=32, seed=11)
    out = nn.flash_attention(q.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
                             v.astype(jnp.bfloat16), causal=False,
                             q_chunk=16, kv_chunk=256)
    want = naive(q, k, v, causal=False)
    assert np.isfinite(np.asarray(out, np.float32)).all()
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want), atol=0.05, rtol=0.05)
