"""Dual-engine dispatch (core/engine.py): sparse path bit-identical to
dense, batched/bias/padding handling, gradients, config-driven wiring.

Bit-exactness strategy: weights are drawn on a dyadic grid (integer
multiples of 2^-8), so every fp32 partial sum in a spike matmul is exact
and the result is independent of accumulation order — sparse-kernel vs
XLA-dot equality is then required to the bit, not to a tolerance. The
skip-vs-no-skip property needs no such trick (skipped blocks contribute
exact zeros) and is pinned on arbitrary normal weights.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine as E
from repro.kernels.spike_matmul import block_occupancy, spike_matmul

SPARSE32 = E.EngineConfig(mode="sparse", block_m=32, block_n=32, block_k=32)


def _spikes(key, shape, density):
    return (jax.random.uniform(key, shape) < density).astype(jnp.float32)


def _dyadic(key, shape):
    return (jax.random.randint(key, shape, -128, 128)
            .astype(jnp.float32)) * (2.0 ** -8)


# at least 3 shapes (incl. non-block-divisible) x 3 sparsity levels
SHAPES = [((2, 2, 32, 64), 48),     # (T, B, L, K), N
          ((4, 1, 48, 96), 80),     # nothing divides 32 evenly
          ((2, 3, 64, 128), 128)]
SPARSITIES = [0.5, 0.8, 0.95]


@pytest.mark.parametrize("lead_k,n", SHAPES)
@pytest.mark.parametrize("sparsity", SPARSITIES)
@pytest.mark.parametrize("bias", [False, True])
def test_spike_linear_sparse_bit_identical_to_dense(lead_k, n, sparsity,
                                                    bias):
    ks = jax.random.split(jax.random.PRNGKey(int(sparsity * 100) + n), 3)
    s = _spikes(ks[0], lead_k, 1.0 - sparsity)
    p = {"w": _dyadic(ks[1], (lead_k[-1], n))}
    if bias:
        p["b"] = _dyadic(ks[2], (n,))
    dense = E.spike_linear(p, s, engine=E.DENSE)
    sparse = E.spike_linear(p, s, engine=SPARSE32)
    assert dense.shape == (*lead_k[:-1], n)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(sparse))


def test_skip_vs_noskip_exact_on_normal_weights():
    """Skipping all-zero blocks only removes exact-zero additions, so the
    sparse kernel equals its own no-skip execution bitwise, any weights."""
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    s = _spikes(ks[0], (96, 160), 0.1)
    s = s.at[:, 32:128].set(0.0)   # coherently-sparse channel stripes
    w = jax.random.normal(ks[1], (160, 64), jnp.float32)
    skipped = spike_matmul(s, w, block_m=32, block_n=32, block_k=32)
    occ = jnp.ones_like(block_occupancy(s, 32, 32))
    forced = spike_matmul(s, w, block_m=32, block_n=32, block_k=32,
                          occupancy=occ)
    np.testing.assert_array_equal(np.asarray(skipped), np.asarray(forced))
    assert float(occ.sum()) > float(
        block_occupancy(s, 32, 32).sum())  # something was actually skipped


def test_spike_linear_gradients_match_dense():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    s = _spikes(ks[0], (2, 2, 32, 64), 0.3)
    w = _dyadic(ks[1], (64, 48))
    b = _dyadic(ks[2], (48,))

    def loss(engine):
        def f(s, w, b):
            y = E.spike_linear({"w": w, "b": b}, s, engine=engine)
            return (y * y).sum()
        return jax.grad(f, argnums=(0, 1, 2))(s, w, b)

    for gd, gs in zip(loss(E.DENSE), loss(SPARSE32)):
        np.testing.assert_allclose(np.asarray(gd), np.asarray(gs),
                                   rtol=1e-5, atol=1e-6)


def test_resolve_mode_auto_uses_flop_floor():
    auto = E.EngineConfig(mode="auto", min_flops=1 << 22)
    assert E.resolve_mode(None, 1024, 1024, 1024) == "dense"
    assert E.resolve_mode(auto, 32, 64, 64) == "dense"
    assert E.resolve_mode(auto, 2048, 512, 512) == "sparse"
    assert E.resolve_mode(E.DENSE, 2048, 512, 512) == "dense"
    assert E.resolve_mode(E.SPARSE, 8, 8, 8) == "sparse"


def test_ambient_engine_scoping():
    assert E.get_engine() is None
    with E.use_engine(SPARSE32):
        assert E.get_engine() is SPARSE32
        with E.use_engine(None):
            assert E.get_engine() is None
        assert E.get_engine() is SPARSE32
    assert E.get_engine() is None


def test_spikingformer_forward_bit_identical_across_engines():
    """The whole model hot path — SSA Q/K/V/O, MLP — produces bitwise-equal
    logits whether matmuls run dense or through the sparse kernel."""
    from repro.configs import get_config
    from repro.models import registry

    cfg = get_config("spikingformer-4-256", smoke=True)
    params = registry.init(cfg, jax.random.PRNGKey(0))
    params = jax.tree_util.tree_map(
        lambda a: jnp.round(a * 256) / 256 if a.dtype == jnp.float32 else a,
        params)
    batch = {"images": jax.random.normal(jax.random.PRNGKey(1),
                                         (2, 16, 16, 3)),
             "labels": jnp.zeros((2,), jnp.int32)}
    with E.use_engine(E.DENSE):
        dense, _ = registry.forward(params, cfg, batch)
    with E.use_engine(SPARSE32):
        sparse, _ = registry.forward(params, cfg, batch)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(sparse))


@pytest.mark.slow
def test_train_step_runs_with_sparse_engine():
    """cfg.engine wires through build_train_step: loss finite, grads flow
    through the custom-VJP sparse path."""
    from repro.configs import get_config
    from repro.launch import steps as steps_lib
    from repro.models import registry
    from repro.optim import adamw

    cfg = get_config("spikingformer-4-256", smoke=True).replace(
        engine=SPARSE32)
    params = registry.init(cfg, jax.random.PRNGKey(0))
    state = registry.init_state(cfg)
    opt = adamw(1e-3)
    step = steps_lib.build_train_step(cfg, opt)
    batch = {"images": jax.random.normal(jax.random.PRNGKey(1),
                                         (2, 16, 16, 3)),
             "labels": jnp.zeros((2,), jnp.int32)}
    _, _, _, metrics, _ = step(params, opt.init(params), jnp.asarray(0),
                               batch, state)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
