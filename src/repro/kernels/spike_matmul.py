"""Block-sparse spike matmul — the sparse engine's MXU adaptation.

FireFly-T's sparse engine skips zero spikes at bit granularity with
multi-lane decoders + out-of-order workers. The MXU's profitable skip
granularity is a whole VMEM tile (DESIGN.md §3): this kernel computes
``y = s @ w`` (spikes x weights) with a per-(block_m x block_k) *occupancy
bitmap* computed upfront (the block-granular analogue of the decoder's
bitmap), and skips the inner dot entirely for all-zero spike blocks via
``@pl.when`` — no weight fetch, no MACs, matching Observation 1 (sparsity
is uniform across the spatial-temporal grid, so whole-tile skips fire
often at >=75% sparsity only when channel-blocks are coherently sparse;
the occupancy reduction itself is the multi-lane decode).

Grid: (nM, nN, nK), K innermost; fp32 accumulator in the revisited output
block. The occupancy map is a tiny (nM, nK) int32 array staged per-step.
A fused bias lands on the last K step, after the final accumulation, so
the dense reference (fp32 dot, then bias) is reproduced term-for-term.

Shapes that don't divide the block sizes are zero-padded: padded K
columns contribute exact fp32 zeros (and all-zero padded blocks are
skipped by occupancy anyway), padded M rows / N columns are sliced off.
``spike_matmul_batched`` folds arbitrary leading ``(T, B, ...)`` dims
into M — the layout every model activation ``(T, B, L, D)`` arrives in.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.bitpack import pad_to_multiple


def _kernel(occ_ref, s_ref, w_ref, o_ref):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(occ_ref[0, 0] > 0)
    def _compute():
        s = s_ref[...].astype(jnp.float32)
        w = w_ref[...].astype(jnp.float32)
        o_ref[...] += jax.lax.dot_general(
            s, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)


def _kernel_bias(occ_ref, s_ref, w_ref, b_ref, o_ref, *, nk):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(occ_ref[0, 0] > 0)
    def _compute():
        s = s_ref[...].astype(jnp.float32)
        w = w_ref[...].astype(jnp.float32)
        o_ref[...] += jax.lax.dot_general(
            s, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _bias():
        o_ref[...] += b_ref[...].astype(jnp.float32)


def block_occupancy(s: jax.Array, block_m: int, block_k: int) -> jax.Array:
    """(M, K) spikes -> (nM, nK) int32 any-nonzero per block."""
    m, k = s.shape
    occ = (s != 0).reshape(m // block_m, block_m, k // block_k,
                           block_k).any(axis=(1, 3))
    return occ.astype(jnp.int32)


def spike_matmul(s: jax.Array, w: jax.Array, *,
                 bias: Optional[jax.Array] = None,
                 block_m: int = 128, block_n: int = 128, block_k: int = 128,
                 occupancy: Optional[jax.Array] = None,
                 out_dtype=None,
                 interpret: Optional[bool] = None) -> jax.Array:
    """y = s @ w (+ bias); s: (M, K) {0,1} spikes, w: (K, N) weights ->
    (M, N) fp32 cast to ``out_dtype`` (default w.dtype; pass jnp.float32
    to keep the raw accumulator — the engine does, so mixed weight/
    activation dtypes round once, not twice). Zero spike blocks are
    skipped; shapes that don't divide the blocks are zero-padded and
    sliced back."""
    m, k = s.shape
    k2, n = w.shape
    assert k == k2
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    sp = pad_to_multiple(pad_to_multiple(s, 0, block_m), 1, block_k)
    wp = pad_to_multiple(pad_to_multiple(w, 0, block_k), 1, block_n)
    mp, kp = sp.shape
    np_ = wp.shape[1]
    occ = block_occupancy(sp, block_m, block_k) if occupancy is None \
        else occupancy

    grid = (mp // block_m, np_ // block_n, kp // block_k)
    in_specs = [
        pl.BlockSpec((1, 1), lambda mi, ni, ki: (mi, ki)),
        pl.BlockSpec((block_m, block_k), lambda mi, ni, ki: (mi, ki)),
        pl.BlockSpec((block_k, block_n), lambda mi, ni, ki: (ki, ni)),
    ]
    operands = [occ, sp, wp]
    if bias is None:
        kernel = _kernel
    else:
        kernel = functools.partial(_kernel_bias, nk=grid[2])
        in_specs.append(pl.BlockSpec((1, block_n),
                                     lambda mi, ni, ki: (0, ni)))
        operands.append(pad_to_multiple(bias.reshape(1, n), 1, block_n))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n),
                               lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(*operands)
    return out[:m, :n].astype(w.dtype if out_dtype is None else out_dtype)


def spike_matmul_batched(s: jax.Array, w: jax.Array, *,
                         bias: Optional[jax.Array] = None,
                         block_m: int = 128, block_n: int = 128,
                         block_k: int = 128,
                         interpret: Optional[bool] = None) -> jax.Array:
    """y = s @ w (+ bias) over arbitrary leading dims.

    s: (T, B, ..., K) spikes; the leading dims fold into the kernel's M —
    the spatial-temporal grid is one flat stream of rows to the sparse
    engine, so whole-tile skips fire across time steps and batch entries
    alike. Returns (T, B, ..., N) in w.dtype.
    """
    lead = s.shape[:-1]
    y = spike_matmul(s.reshape(-1, s.shape[-1]), w, bias=bias,
                     block_m=block_m, block_n=block_n, block_k=block_k,
                     interpret=interpret)
    return y.reshape(*lead, w.shape[1])
