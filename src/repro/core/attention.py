"""Spiking self-attention (SSA) primitives.

The binary engine's workload: given spiking ``Q, K, V`` in {0,1},

    scores  = Q @ K^T                       (AND-PopCount == binary dot)
    attn    = binarize(scores * scale, Δ_s) (binary attention, Shen et al.)
    context = attn @ V
    out     = SN(context)  or  binarize(context * scale2, Δ_o)

No softmax — which is exactly why the whole thing fuses into a single-pass
Pallas kernel with no running-max bookkeeping (see kernels/spike_attention).

Engine dispatch (DESIGN.md §4): :func:`spiking_attention` consults the
ambient :class:`~repro.core.engine.EngineConfig` (installed by the step
builders from ``ModelConfig.engine``) and routes to one of the binary
engine's three execution targets — the pure-jnp reference below, the
fused MXU Pallas kernel, or the bit-packed AND-PopCount port. All three
are bit-identical on spike inputs: {0,1} dot products accumulate exact
small integers in fp32 regardless of tiling order, and the threshold
compare is the shared ``binarize`` expression.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from .engine import (EngineConfig, annotate, get_engine,
                     resolve_binary_mode)
from .spiking import SpikingConfig, binarize


def binary_attention_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """Integer spike-overlap counts: (..., Lq, d) x (..., Lk, d) -> (..., Lq, Lk).

    Operands are {0,1}-valued; the result equals AND-PopCount along d.
    """
    return jnp.einsum("...qd,...kd->...qk", q, k,
                      preferred_element_type=jnp.float32)


def spiking_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      cfg: SpikingConfig,
                      delta_score: jax.Array | float = 0.0,
                      scale: Optional[float] = None,
                      causal: bool = False,
                      engine: Optional[EngineConfig] = None) -> jax.Array:
    """Binary spiking attention over the last two dims ``(L, d_head)``.

    Args:
      q, k, v: ``(..., L, d)`` spike tensors ({0,1} values, float dtype).
        Leading dims (batch, heads, time steps in any order) fold into the
        binary engine's BH axis.
      cfg: spiking config (binarize_scores toggles binary attention vs the
        raw spiking attention of Spikformer/Spikingformer Eq. 2).
      delta_score: learnable binarization threshold Δ for the scores.
      scale: score scale; defaults to 1/sqrt(d) per Eq. 2.
      causal: mask future positions (token SSA; vision SSA is bidirectional).
      engine: explicit engine override; ``None`` uses the ambient engine
        (see ``core.engine.use_engine``), no ambient engine means the
        pure-jnp reference path.

    Returns:
      context ``(..., L, d)`` — binarized scores times V (membrane currents;
      the caller applies the output spiking neuron / residual).
    """
    d = q.shape[-1]
    l = q.shape[-2]
    # python-float scale (not a traced 1/jnp.sqrt) so the kernel paths can
    # close over it statically under jit; every engine mode then scales by
    # the identical value, which the cross-mode bit-parity tests rely on
    scale = (1.0 / math.sqrt(d)) if scale is None else scale
    engine = engine if engine is not None else get_engine()
    bh = 1
    for dim in q.shape[:-2]:
        bh *= dim
    mode = resolve_binary_mode(engine, bh, l, d)
    if mode != "jnp":
        from repro.kernels import ops as kops  # lazy: keeps core importable
        fold = lambda u: u.reshape(bh, l, d)
        with annotate(f"binary_engine.{mode}"):
            out = kops.binary_attention(
                fold(q), fold(k), fold(v), scale=float(scale),
                delta=delta_score, causal=causal,
                binarize_scores=cfg.binarize_scores,
                alpha=cfg.surrogate_alpha,
                use_popcount=(mode == "popcount"),
                block_q=engine.attn_block_q, block_k=engine.attn_block_k)
        return out.reshape(q.shape)
    with annotate("binary_engine.jnp"):
        scores = binary_attention_scores(q, k) * scale
        if cfg.binarize_scores:
            attn = binarize(scores, delta_score, cfg.surrogate_alpha)
        else:
            attn = scores
        if causal:
            mask = jnp.tril(jnp.ones((l, l), bool))
            attn = jnp.where(mask, attn, 0.0)
        return jnp.einsum("...qk,...kd->...qd", attn, v,
                          preferred_element_type=jnp.float32).astype(q.dtype)
