"""Fused SSA layer step — both overlay engines in one pipelined kernel.

The paper's headline schedule (Fig. 5, Section III-C) runs the sparse
engine and the binary engine *concurrently*: while the binary engine
computes ``QK^T_h`` / ``QK^T V_h`` for head *h*, the sparse engine is
already projecting Q/K/V for head *h+1*. The sequential reproduction
(``models/spikingformer._ssa``: four ``linear`` calls, then attention)
never overlaps anything; this kernel makes the overlap structural.

Grid ``(B, H, 4)``: for every (batch, head) pair, three sparse-engine
phases (Q/K/V projection tiles — per-time-step spike x weight dots with
an occupancy skip, plus the projection epilogue: BN affine + LIF for the
vision family, RoPE + LIF for the token family) followed by one
binary-engine phase (AND-PopCount score + value tiles). Adjacent grid
steps ``(b, h, attend)`` -> ``(b, h+1, project-Q)`` are exactly the
Fig. 5 adjacency: on TPU, Pallas's pipelined grid prefetches head
h+1's weight block while head h's attention tiles occupy the MXU, and
the per-time-step spike slabs stream through an explicit ping-pong VMEM
scratch via ``pltpu.make_async_copy`` (the BRAM double-buffer of the
overlay, DESIGN.md §10). Q/K/V spike trains persist across the four
phases in VMEM scratch — the L x d_head attention operands never leave
the chip.

Bit-exactness (DESIGN.md §4 contract): every projection contracts the
*full* K dim in one fp32-accumulated dot (no K tiling — term-for-term
the dense reference), the epilogues repeat the reference expressions
(``nn.batchnorm`` eval affine, ``nn.rope``, ``core.spiking.lif_step``)
on identical dtypes, and the attention phase is the integer-exact
binary dataflow. ``reference_bundle`` below is the sequential oracle
the kernel is pinned against bitwise — and the recompute target of the
fused path's custom VJP (``core.engine``).

Measurement (the "measured, not modeled" hidden fraction): the kernel
counts *executed* compute sub-steps per (head, phase) — an all-dark
spike slab skips its dot via ``lax.cond`` and is not counted — into an
``(H, 4)`` int32 side output. ``core.dual_engine.fused_step_metrics``
feeds those counts to the Fig. 5 event schedule, so the bench's
``hidden_fraction`` derives from the kernel's actual execution, not
from the analytic MAC model. Counts are data-deterministic, so CI gates
them (``benchmarks/check_regression.py``).

Like the decoded datapath (§9), this kernel is validated in interpret
mode (the container's execution mode); Mosaic lowering on a real TPU is
future work, so ``overlap='auto'`` never volunteers it there.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.spiking import SpikingConfig, binarize, lif_scan

FAMILIES = ("bn", "rope")
PHASES = ("q", "k", "v", "attend")


def _kernel(x_ref, w_ref, scale_ref, aux_ref, delta_ref, o_ref, cnt_ref,
            qs, ks, vs, xbuf, sem, *, family, t_steps, l, k_dim, head_dim,
            scale, causal, binarize_scores, decay, v_th, soft_reset, eps,
            has_scale, dtype):
    b, h, p = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    half = head_dim // 2

    @pl.when((b == 0) & (p == 0))
    def _init_counts():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    def project(dst, col, roped):
        # Per-time-step spike/current slabs stream through a 2-slot
        # ping-pong VMEM scratch: the async copy for step t+1 is in
        # flight while step t's dot runs (the overlay's BRAM double
        # buffer; on CPU interpret the copies complete synchronously,
        # values are identical either way).
        def copy(t):
            return pltpu.make_async_copy(x_ref.at[0, t], xbuf.at[t % 2],
                                         sem.at[t % 2])

        copy(0).start()
        w = w_ref[0]
        nexec = jnp.int32(0)
        vals = []
        for t in range(t_steps):
            if t + 1 < t_steps:
                copy(t + 1).start()
            copy(t).wait()
            slab = xbuf[t % 2]                       # (L, K)
            occ = jnp.any(slab != 0)
            # occupancy skip: a dark slab contributes exact fp32 zeros,
            # so skipping its dot is bitwise-free — and *measured*: only
            # executed dots reach the counts output.
            acc = jax.lax.cond(
                occ,
                lambda s=slab: jax.lax.dot_general(
                    s, w, (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32),
                lambda: jnp.zeros((l, head_dim), jnp.float32))
            nexec += occ.astype(jnp.int32)
            vals.append(acc)
        cur = jnp.stack(vals)                        # (T, L, hd) fp32
        if has_scale:
            # quantized codes: per-output-channel scale in the epilogue,
            # exactly dense_quant_linear's expression order
            cur = cur * scale_ref[0].astype(jnp.float32)
        y = cur.astype(dtype)                        # linear emits act dtype
        if family == "bn":
            mean, var = aux_ref[0, 0], aux_ref[0, 1]
            sc, bi = aux_ref[0, 2], aux_ref[0, 3]
            y32 = y.astype(jnp.float32)
            y32 = (y32 - mean) * jax.lax.rsqrt(var + eps)
            y32 = y32 * sc + bi                      # nn.batchnorm (eval)
            y = y32.astype(dtype)
        elif roped:                                  # rope family: q, k only
            cos = aux_ref[0][None]                   # (1, L, half)
            sin = aux_ref[1][None]
            x1 = y[..., :half].astype(jnp.float32)
            x2 = y[..., half:].astype(jnp.float32)
            y = jnp.concatenate([x1 * cos - x2 * sin,
                                 x2 * cos + x1 * sin], -1).astype(dtype)
        # LIF over the time axis (core.spiking.lif_step semantics)
        u = jnp.zeros((l, head_dim), dtype)
        for t in range(t_steps):
            u = decay * u + y[t]
            s_t = (u - v_th >= 0).astype(dtype)
            u = u - s_t * v_th if soft_reset else u * (1.0 - s_t)
            dst[t] = s_t
        cnt_ref[0, col] += nexec

    @pl.when(p == 0)
    def _q():
        project(qs, 0, roped=True)

    @pl.when(p == 1)
    def _k():
        project(ks, 1, roped=True)

    @pl.when(p == 2)
    def _v():
        project(vs, 2, roped=False)

    @pl.when(p == 3)
    def _attend():
        for t in range(t_steps):
            q, k, v = qs[t], ks[t], vs[t]
            sc = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                     preferred_element_type=jnp.float32)
            sc = sc * scale
            if binarize_scores:
                a = (sc - delta_ref[0, 0] >= 0).astype(jnp.float32)
            else:
                a = sc
            if causal:
                rows = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
                cols = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
                a = jnp.where(rows >= cols, a, 0.0)
            ctx = jax.lax.dot_general(a, v, (((1,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
            o_ref[0, t] = ctx.astype(dtype)
        cnt_ref[0, 3] += jnp.int32(2 * t_steps)


def fused_ssa(x: jax.Array, w3: jax.Array, scale3: Optional[jax.Array],
              aux: jax.Array, delta, *, family: str, num_heads: int,
              head_dim: int, scale: float, causal: bool = False,
              binarize_scores: bool = True, decay: float = 0.5,
              v_th: float = 1.0, soft_reset: bool = False,
              eps: float = 1e-5,
              interpret: Optional[bool] = None
              ) -> Tuple[jax.Array, jax.Array]:
    """Fused projection+attention SSA step (forward only — the engine
    wraps it in a custom VJP whose bwd recomputes ``reference_bundle``).

    Args:
      x: ``(T, B, L, K)`` — {0,1} spikes (vision family) or normed
        currents (token family), activation dtype.
      w3: ``(3, K, H*hd)`` stacked Q/K/V weights (quantized codes arrive
        pre-cast to the activation dtype, mirroring dense_quant_linear).
      scale3: ``(3, H*hd)`` fp32 per-channel quantization scales, or
        ``None`` for fp-native weights.
      aux: projection epilogue operand — family ``'bn'``: ``(3, 4,
        H*hd)`` fp32 rows ``[mean, var, scale, bias]`` per projection
        (eval-mode running stats + affine); family ``'rope'``: ``(2, L,
        hd//2)`` fp32 ``[cos; sin]`` tables (applied to Q/K only).
      delta: score binarization threshold (scalar).
      scale: python-float score scale (1/sqrt(hd) per Eq. 2).

    Returns:
      (context ``(T, B, L, H*hd)`` activation dtype,
       counts ``(H, 4)`` int32 — *executed* dot sub-steps per head for
       the Q/K/V projection phases and the attention phase).
    """
    if family not in FAMILIES:
        raise ValueError(f"unknown fused-SSA family {family!r} "
                         f"(expected bn|rope)")
    t, b, l, k_dim = x.shape
    q_dim = num_heads * head_dim
    assert w3.shape == (3, k_dim, q_dim), w3.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    dtype = x.dtype
    xb = jnp.transpose(x, (1, 0, 2, 3))              # (B, T, L, K)
    delta_op = jnp.asarray(delta, jnp.float32).reshape(1, 1)

    w_idx = lambda bi, hi, pi: (jnp.minimum(pi, 2), 0, hi)
    in_specs = [
        pl.BlockSpec((1, t, l, k_dim), lambda bi, hi, pi: (bi, 0, 0, 0)),
        pl.BlockSpec((1, k_dim, head_dim), w_idx),
    ]
    operands = [xb, w3]
    has_scale = scale3 is not None
    if not has_scale:
        # uniform kernel signature; multiplying fp32 by 1.0 is a bitwise
        # identity, so the fp-native path is unaffected
        scale3 = jnp.ones((3, q_dim), jnp.float32)
    in_specs.append(pl.BlockSpec(
        (1, head_dim), lambda bi, hi, pi: (jnp.minimum(pi, 2), hi)))
    operands.append(scale3.astype(jnp.float32))
    if family == "bn":
        assert aux.shape == (3, 4, q_dim), aux.shape
        in_specs.append(pl.BlockSpec(
            (1, 4, head_dim), lambda bi, hi, pi: (jnp.minimum(pi, 2), 0, hi)))
    else:
        assert aux.shape == (2, l, head_dim // 2), aux.shape
        in_specs.append(pl.BlockSpec(
            (2, l, head_dim // 2), lambda bi, hi, pi: (0, 0, 0)))
    operands.append(aux.astype(jnp.float32))
    in_specs.append(pl.BlockSpec((1, 1), lambda bi, hi, pi: (0, 0)))
    operands.append(delta_op)

    kernel = functools.partial(
        _kernel, family=family, t_steps=t, l=l, k_dim=k_dim,
        head_dim=head_dim, scale=float(scale), causal=causal,
        binarize_scores=binarize_scores, decay=float(decay),
        v_th=float(v_th), soft_reset=soft_reset, eps=float(eps),
        has_scale=has_scale, dtype=dtype)

    out, cnt = pl.pallas_call(
        kernel,
        grid=(b, num_heads, 4),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, t, l, head_dim),
                         lambda bi, hi, pi: (bi, 0, 0, hi)),
            pl.BlockSpec((1, 4), lambda bi, hi, pi: (hi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, l, q_dim), dtype),
            jax.ShapeDtypeStruct((num_heads, 4), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((t, l, head_dim), dtype),     # q spikes
            pltpu.VMEM((t, l, head_dim), dtype),     # k spikes
            pltpu.VMEM((t, l, head_dim), dtype),     # v spikes
            pltpu.VMEM((2, l, k_dim), dtype),        # ping-pong spike slab
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(*operands)
    return jnp.transpose(out, (1, 0, 2, 3)), cnt


def reference_bundle(x: jax.Array, w3: jax.Array,
                     scale3: Optional[jax.Array], aux: jax.Array, delta,
                     scfg: SpikingConfig, *, family: str, num_heads: int,
                     head_dim: int, scale: float, causal: bool = False,
                     eps: float = 1e-5) -> jax.Array:
    """The sequential oracle: term-for-term the ``overlap='off'`` layer
    composition (dense fp32-accumulated projections -> BN affine / RoPE
    -> ``lif_scan`` -> jnp binary attention), on the same raw operands
    the kernel sees. The fused custom VJP recomputes through this in
    bwd, so fused gradients are the sequential path's gradients by
    construction (surrogate LIF/binarize jvps included)."""
    t, b, l, _ = x.shape
    q_dim = num_heads * head_dim
    half = head_dim // 2
    projected = []
    for j in range(3):
        acc = jnp.dot(x, w3[j], preferred_element_type=jnp.float32)
        if scale3 is not None:
            acc = acc * scale3[j].astype(jnp.float32)
        y = acc.astype(x.dtype)
        if family == "bn":
            mean, var = aux[j, 0], aux[j, 1]
            y32 = y.astype(jnp.float32)
            y32 = (y32 - mean) * jax.lax.rsqrt(var + eps)
            y32 = y32 * aux[j, 2] + aux[j, 3]
            y = y32.astype(x.dtype)
        elif j < 2:                                  # rope on q, k
            y5 = y.reshape(t, b, l, num_heads, head_dim)
            cos = aux[0][None, None, :, None, :]
            sin = aux[1][None, None, :, None, :]
            x1 = y5[..., :half].astype(jnp.float32)
            x2 = y5[..., half:].astype(jnp.float32)
            y = jnp.concatenate([x1 * cos - x2 * sin,
                                 x2 * cos + x1 * sin],
                                -1).astype(x.dtype).reshape(t, b, l, q_dim)
        s_j, _ = lif_scan(y, scfg)
        projected.append(s_j)
    fold = lambda u: u.reshape(t * b, l, num_heads,
                               head_dim).transpose(0, 2, 1, 3)
    q, k, v = (fold(u) for u in projected)
    scores = jnp.einsum("...qd,...kd->...qk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if scfg.binarize_scores:
        attn = binarize(scores, delta, scfg.surrogate_alpha)
    else:
        attn = scores
    if causal:
        mask = jnp.tril(jnp.ones((l, l), bool))
        attn = jnp.where(mask, attn, 0.0)
    ctx = jnp.einsum("...qk,...kd->...qd", attn, v,
                     preferred_element_type=jnp.float32).astype(q.dtype)
    return ctx.transpose(0, 2, 1, 3).reshape(t, b, l, q_dim)
