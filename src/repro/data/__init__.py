from .pipeline import (DataConfig, SyntheticImages, SyntheticLM,
                       make_pipeline)
