"""repro.quant — the int8/int4 weight datapath of the sparse engine.

Bit-exactness strategy (DESIGN.md §8): in *dyadic* mode every scale is a
power of two, so (a) dequantized weights ``qw * 2^-e`` are exact fp32
values, (b) multiplying by the scale commutes exactly with fp32 rounding
and addition (no overflow at these magnitudes), and (c) on {0,1} spike
inputs every partial sum is a small integer held exactly by both the
kernel's int32 accumulator and the reference's fp32 accumulator. The
quantized path is therefore pinned **bitwise** (integer / fp32-exact
equality, no tolerances) against ``dense_spike_linear`` on the
dequantized weights — per layer and through the whole model.

Calibrated (non-dyadic) parity is statistical by nature: an 0.4% weight
perturbation flips LIF spikes and binary-attention bits near threshold,
so whole-model logit deltas are spike-flip dominated (the quantized
datapath itself still matches its dequantized-fp32 twin to float
rounding, pinned separately). The stated tolerances below are ~1.5x the
measured deltas at fixed seeds.
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine as E
from repro.quant import (calibrate, dequantize_tree, dequantize_weight,
                         fake_quant, fake_quant_tree, footprint_report,
                         pack_int4, quantize_tree, quantize_weight,
                         symmetric_scale, unpack_int4)

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    from _propcheck import given, settings, strategies as st

SPARSE32 = E.EngineConfig(mode="sparse", block_m=32, block_n=32,
                          block_k=32)


def _spikes(key, shape, density):
    return (jax.random.uniform(key, shape) < density).astype(jnp.float32)


# same grid as tests/test_engine.py: 3 shapes (incl. non-block-divisible)
# x 3 sparsity levels x bias on/off — now x both quantized dtypes
SHAPES = [((2, 2, 32, 64), 48),
          ((4, 1, 48, 96), 80),
          ((2, 3, 64, 128), 128)]
SPARSITIES = [0.5, 0.8, 0.95]


# ---------------------------------------------------------------------------
# kernel-level bitwise pinning (dyadic scales)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("lead_k,n", SHAPES)
@pytest.mark.parametrize("sparsity", SPARSITIES)
@pytest.mark.parametrize("bias", [False, True])
@pytest.mark.parametrize("dtype", ["int8", "int4"])
def test_quant_kernel_bitwise_vs_dense_on_dequantized(lead_k, n, sparsity,
                                                      bias, dtype):
    """The int-accumulating kernel == fp32 dense reference on dequantized
    weights, to the bit, across shapes x sparsities x bias x dtypes."""
    ks = jax.random.split(jax.random.PRNGKey(int(sparsity * 100) + n), 3)
    s = _spikes(ks[0], lead_k, 1.0 - sparsity)
    w = jax.random.normal(ks[1], (lead_k[-1], n), jnp.float32)
    q = quantize_weight(w, dtype, dyadic=True)
    if bias:
        q["b"] = jax.random.normal(ks[2], (n,), jnp.float32)
    ref_p = {"w": dequantize_weight(q, k=lead_k[-1])}
    if bias:
        ref_p["b"] = q["b"]
    ref = E.spike_linear(ref_p, s, engine=E.DENSE)
    out_sparse = E.spike_linear(q, s, engine=SPARSE32)
    out_dense = E.spike_linear(q, s, engine=E.DENSE)
    assert ref.shape == (*lead_k[:-1], n)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out_sparse))
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out_dense))


def test_quant_kernel_occupancy_actually_skips():
    """Dark channel stripes drop whole tiles on the quantized path too,
    and skipping changes nothing (skipped blocks contribute exact
    zeros)."""
    from repro.kernels.spike_matmul import (block_occupancy,
                                            quant_spike_matmul)
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    s = _spikes(ks[0], (96, 160), 0.1)
    s = s.at[:, 32:128].set(0.0)
    w = jax.random.normal(ks[1], (160, 64), jnp.float32)
    q = quantize_weight(w, "int8")
    occ = block_occupancy(s, 32, 32)
    assert float(occ.mean()) < 1.0            # something to skip
    skipped = quant_spike_matmul(s, q["qw"], q["scale"], block_m=32,
                                 block_n=32, block_k=32)
    forced = quant_spike_matmul(s, q["qw"], q["scale"], block_m=32,
                                block_n=32, block_k=32,
                                occupancy=jnp.ones_like(occ))
    np.testing.assert_array_equal(np.asarray(skipped), np.asarray(forced))


def test_quant_kernel_counts_above_127_do_not_wrap():
    """The wo projection consumes binary-attention *counts* (up to L,
    not {0,1}); counts=True gives them int32 lanes — the int8 spike cast
    would silently wrap at 128. Pinned bitwise against the dense
    reference on dequantized dyadic weights."""
    ks = jax.random.split(jax.random.PRNGKey(9), 2)
    # integer counts up to 300: attention context at L=300
    x = jnp.floor(jax.random.uniform(ks[0], (48, 64)) * 301.0)
    assert float(x.max()) > 127
    w = jax.random.normal(ks[1], (64, 32), jnp.float32)
    q = quantize_weight(w, "int8", dyadic=True)
    ref = E.spike_linear({"w": dequantize_weight(q)}, x, engine=E.DENSE)
    out = E.spike_linear(q, x, engine=SPARSE32, counts=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))
    # and the model's count call site routes counts=True end to end:
    # without it, the same input through the spike path would wrap
    wrapped = E.spike_linear(q, x, engine=SPARSE32, counts=False)
    assert not np.array_equal(np.asarray(ref), np.asarray(wrapped))


def test_quant_gradients_flow_through_activations():
    """jax.grad through the quantized sparse path: ds matches the dense
    path on dequantized weights; scale/bias get real grads."""
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    s = _spikes(ks[0], (2, 2, 32, 64), 0.3)
    w = jax.random.normal(ks[1], (64, 48), jnp.float32)
    q = quantize_weight(w, "int8", dyadic=True)
    w_deq = dequantize_weight(q)

    def loss_q(s):
        return (E.spike_linear(q, s, engine=SPARSE32) ** 2).sum()

    def loss_d(s):
        return (E.spike_linear({"w": w_deq}, s, engine=E.DENSE) ** 2).sum()

    gq = jax.grad(loss_q)(s)
    gd = jax.grad(loss_d)(s)
    np.testing.assert_allclose(np.asarray(gq), np.asarray(gd),
                               rtol=1e-5, atol=1e-6)
    gs = jax.grad(lambda sc: (E.spike_linear(
        {**q, "scale": sc}, s, engine=SPARSE32) ** 2).sum())(q["scale"])
    assert float(jnp.abs(gs).max()) > 0


# ---------------------------------------------------------------------------
# quantizer properties
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=1, max_value=33),
       st.integers(min_value=1, max_value=9),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_int4_pack_roundtrip(k, n, seed):
    q = jax.random.randint(jax.random.PRNGKey(seed), (k, n), -7, 8,
                           jnp.int32).astype(jnp.int8)
    out = unpack_int4(pack_int4(q), k)
    assert out.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(out), np.asarray(q))


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1),
       st.sampled_from(["int8", "int4"]))
def test_dyadic_scales_are_powers_of_two_and_codes_in_range(seed, dtype):
    w = jax.random.normal(jax.random.PRNGKey(seed), (24, 12),
                          jnp.float32) * 10.0 ** ((seed % 7) - 3)
    q = quantize_weight(w, dtype, dyadic=True)
    exps = np.log2(np.asarray(q["scale"], np.float64))
    np.testing.assert_array_equal(exps, np.round(exps))
    codes = np.asarray(q["qw"]) if dtype == "int8" \
        else np.asarray(unpack_int4(q["qw"], 24))
    qmax = 127 if dtype == "int8" else 7
    assert codes.max() <= qmax and codes.min() >= -qmax
    # dyadic dequantization is exact: re-quantizing reproduces the codes
    q2 = quantize_weight(dequantize_weight(q, k=24), dtype, dyadic=True)
    np.testing.assert_array_equal(np.asarray(q["qw"]), np.asarray(q2["qw"]))


def test_int4_odd_k_roundtrips_unpacked():
    """Odd-K int4 linears keep int8-stored 4-bit codes (packing only
    even K keeps the packed shape self-describing): dequantize_tree
    restores the exact original shape, no pad row leaks."""
    w = jax.random.normal(jax.random.PRNGKey(4), (5, 4), jnp.float32)
    qt = quantize_tree({"lin": {"w": w}}, "int4")
    assert qt["lin"]["qw"].dtype == jnp.int8          # unpacked codes
    assert int(jnp.abs(qt["lin"]["qw"]).max()) <= 7   # still 4-bit values
    dq = dequantize_tree(qt)
    assert dq["lin"]["w"].shape == (5, 4)
    # even K packs and round-trips shape-exactly with no k hint
    qt2 = quantize_tree({"lin": {"w": jnp.ones((6, 4))}}, "int4")
    assert qt2["lin"]["qw"].dtype == jnp.uint8
    assert dequantize_tree(qt2)["lin"]["w"].shape == (6, 4)


def test_footprint_excludes_norm_scales():
    """Only quantized-weight payloads (qw + their scales) count — a
    norm's {"scale"} param must not skew the compression metric."""
    tree = {"lin": {"w": jnp.ones((256, 256), jnp.float32)},
            "norm": {"scale": jnp.ones((256,), jnp.float32)}}
    rep = footprint_report(tree, quantize_tree(tree, "int8"))
    assert rep["compression"] == pytest.approx(4 * 256 / (256 + 4))


def test_quantize_tree_structure_and_selectivity():
    from repro.configs import get_config
    from repro.models import registry

    cfg = get_config("spikingformer-lm", smoke=True)
    params = registry.init(cfg, jax.random.PRNGKey(0))
    qp = quantize_tree(params, "int8")
    # linears (incl. scan-stacked) quantized, per-layer scales kept
    assert qp["layers"]["wq"]["qw"].dtype == jnp.int8
    assert qp["layers"]["wq"]["qw"].shape == params["layers"]["wq"]["w"].shape
    assert qp["layers"]["wq"]["scale"].shape == (cfg.num_layers, cfg.q_dim)
    assert qp["lm_head"]["qw"].dtype == jnp.int8
    # embeddings / norms / thresholds untouched
    assert qp["embed"]["table"].dtype == params["embed"]["table"].dtype
    assert qp["final_norm"]["scale"].dtype == jnp.float32
    assert qp["layers"]["delta"].dtype == params["layers"]["delta"].dtype
    # int4 halves the stacked K rows
    q4 = quantize_tree(params, "int4")
    l, k, n = params["layers"]["wq"]["w"].shape
    assert q4["layers"]["wq"]["qw"].shape == (l, (k + 1) // 2, n)
    assert q4["layers"]["wq"]["qw"].dtype == jnp.uint8
    # path selector keeps the head in fp
    q_sel = quantize_tree(params, "int8",
                          select=lambda p: not p.startswith("lm_head"))
    assert "w" in q_sel["lm_head"] and "qw" not in q_sel["lm_head"]
    # dequantize_tree restores the {"w"} structure everywhere
    dq = dequantize_tree(qp)
    assert jax.tree_util.tree_structure(dq) == \
        jax.tree_util.tree_structure(params)
    with pytest.raises(ValueError):
        quantize_tree(params, "int2")


# ---------------------------------------------------------------------------
# whole-model parity
# ---------------------------------------------------------------------------


def _lm_setup():
    from repro.configs import get_config
    from repro.models import registry

    cfg = get_config("spikingformer-lm", smoke=True)
    params = registry.init(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (4, 16),
                                          0, cfg.vocab_size)}
    return cfg, params, batch, registry


@pytest.mark.parametrize("dtype", ["int8", "int4"])
def test_whole_model_dyadic_quantization_bitwise(dtype):
    """Quantized spikingformer-lm forward == fp32 forward on the
    dequantized tree, bitwise — the whole datapath (analog projections,
    spiking SSA, LM head) under dyadic scales."""
    cfg, params, batch, registry = _lm_setup()
    qp = quantize_tree(params, dtype, dyadic=True)
    out_q, _ = registry.forward(qp, cfg, batch)
    out_ref, _ = registry.forward(dequantize_tree(qp), cfg, batch)
    np.testing.assert_array_equal(np.asarray(out_q), np.asarray(out_ref))


def test_whole_model_quant_engine_parity():
    """Quantized spikingformer (vision) logits are bitwise identical
    whether the spike matmuls run dense or through the int8 sparse
    kernel — quantization composes with dual-engine dispatch."""
    from repro.configs import get_config
    from repro.models import registry

    cfg = get_config("spikingformer-4-256", smoke=True)
    params = registry.init(cfg, jax.random.PRNGKey(0))
    qp = quantize_tree(params, "int8", dyadic=True)
    batch = {"images": jax.random.normal(jax.random.PRNGKey(1),
                                         (2, 16, 16, 3)),
             "labels": jnp.zeros((2,), jnp.int32)}
    with E.use_engine(E.DENSE):
        dense, _ = registry.forward(qp, cfg, batch)
    with E.use_engine(SPARSE32):
        sparse, _ = registry.forward(qp, cfg, batch)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(sparse))


# stated tolerances for calibrated (non-dyadic) PTQ: normalized logit MAE
# (mean |Δ| / std(fp32 logits)) at fixed seeds; ~1.5x measured headroom.
# Spike-flip sensitivity dominates these numbers (see module docstring).
LM_TOL = {"int8": 0.35, "int4": 0.75}
VISION_TOL = {"int8": 0.25, "int4": 0.55}


@pytest.mark.parametrize("dtype", ["int8", "int4"])
def test_whole_model_calibrated_logit_parity_lm(dtype):
    cfg, params, batch, registry = _lm_setup()
    qp, rep = calibrate(cfg, params, batch, dtype)
    assert rep["chosen"]["logit_mae_rel"] <= LM_TOL[dtype], rep["chosen"]
    out, _ = registry.forward(qp, cfg, batch)
    ref, _ = registry.forward(params, cfg, batch)
    assert float(jnp.abs(out - ref).mean()) == \
        pytest.approx(rep["chosen"]["logit_mae"], rel=1e-5)
    if dtype == "int8":
        assert rep["chosen"]["argmax_agree"] >= 0.5


@pytest.mark.parametrize("dtype", ["int8", "int4"])
def test_whole_model_calibrated_logit_parity_vision(dtype):
    from repro.configs import get_config
    from repro.models import registry

    cfg = get_config("spikingformer-4-256", smoke=True)
    params = registry.init(cfg, jax.random.PRNGKey(0))
    # scale init up so LIF neurons fire (unit init is silent -> vacuous)
    params = jax.tree_util.tree_map(
        lambda a: a * 3.0 if a.ndim >= 2 else a, params)
    state = registry.init_state(cfg)
    batch = {"images": 2.0 * jax.random.normal(jax.random.PRNGKey(2),
                                               (4, 16, 16, 3)),
             "labels": jnp.zeros((4,), jnp.int32)}
    ref, aux = registry.forward(params, cfg, batch, state=state)
    assert float(aux["fire_rate"]) > 0.1      # the model actually spikes
    _, rep = calibrate(cfg, params, batch, dtype, state=state)
    assert rep["chosen"]["logit_mae_rel"] <= VISION_TOL[dtype], \
        rep["chosen"]


# ---------------------------------------------------------------------------
# QAT: fake-quant + straight-through estimator
# ---------------------------------------------------------------------------


def test_fake_quant_matches_serving_quantizer():
    """QAT's forward rounding is the exact serving quantizer: zero
    train/serve mismatch."""
    w = jax.random.normal(jax.random.PRNGKey(0), (32, 16), jnp.float32)
    fq = fake_quant(w, 8)
    deq = dequantize_weight(quantize_weight(w, "int8"))
    np.testing.assert_array_equal(np.asarray(fq), np.asarray(deq))


def test_fake_quant_ste_gradient_is_identity():
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 8), jnp.float32)
    c = jax.random.normal(jax.random.PRNGKey(2), (16, 8), jnp.float32)
    g = jax.grad(lambda w: jnp.vdot(fake_quant(w, 8), c))(w)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(c))


def test_qat_train_step_grads_reach_masters():
    """build_train_step(qat=...): loss finite, nonzero grads reach the
    fp32 master weights through the STE, masters move."""
    from repro.configs import get_config
    from repro.launch import steps as steps_lib
    from repro.models import registry
    from repro.optim import adamw

    cfg = get_config("spikingformer-lm", smoke=True)
    params = registry.init(cfg, jax.random.PRNGKey(0))
    opt = adamw(1e-2)
    step = steps_lib.build_train_step(cfg, opt, qat="int8")
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8),
                                          0, cfg.vocab_size)}
    new_params, _, _, metrics = jax.jit(step)(params, opt.init(params),
                                              jnp.asarray(0), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    moved = float(jnp.abs(new_params["layers"]["wq"]["w"] -
                          params["layers"]["wq"]["w"]).max())
    assert moved > 0


def test_qat_forward_equals_quantized_serving_forward():
    """Training loss sees exactly the logits the quantized serve path
    produces (fake-quant tree == dequantized quantize_tree)."""
    cfg, params, batch, registry = _lm_setup()
    fq_out, _ = registry.forward(fake_quant_tree(params, "int8"), cfg,
                                 batch)
    q_out, _ = registry.forward(
        dequantize_tree(quantize_tree(params, "int8")), cfg, batch)
    np.testing.assert_array_equal(np.asarray(fq_out), np.asarray(q_out))


# ---------------------------------------------------------------------------
# checkpoint round-trips (int payloads, scales in the manifest)
# ---------------------------------------------------------------------------


def _tree_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype, (x.dtype, y.dtype)
        np.testing.assert_array_equal(x, y)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1),
       st.booleans())
def test_checkpoint_roundtrip_preserves_non_fp32_leaves(seed, use_template):
    """save->restore is bitwise + dtype-exact for int8 codes, packed-int4
    uint8, packed-KV uint32, bf16, and mixed nested containers — with a
    template and template-free."""
    from repro.checkpoint.manager import restore_tree, save_tree

    rng = np.random.default_rng(seed)
    tree = {
        "q": {"qw": jnp.asarray(rng.integers(-127, 128, (5, 3)), jnp.int8),
              "scale": jnp.asarray(rng.random(3), jnp.float32)},
        "packed": jnp.asarray(rng.integers(0, 2 ** 32, (2, 4),
                                           dtype=np.uint64), jnp.uint32),
        "nibbles": jnp.asarray(rng.integers(0, 256, (3, 2)), jnp.uint8),
        "bf16": jnp.asarray(rng.random((4,)), jnp.bfloat16),
        "seq": [jnp.asarray([1, 2], jnp.int32),
                {"deep": jnp.asarray(rng.random((2, 2)), jnp.float32)}],
        "tup": (jnp.zeros((2,), jnp.int8),),
        "empty_list": [],
        "empty_dict": {},
    }
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck")
        save_tree(tree, path, 11, extra={"quant": {"dtype": "int8"}})
        restored, step, extra = restore_tree(
            path, template=tree if use_template else None)
        assert step == 11 and extra == {"quant": {"dtype": "int8"}}
        _tree_equal(tree, restored)
        if not use_template:
            assert isinstance(restored["seq"], list)
            assert isinstance(restored["tup"], tuple)
            # empty containers survive the template-free rebuild too
            assert restored["empty_list"] == []
            assert restored["empty_dict"] == {}


def test_template_free_restore_rejects_legacy_manifest():
    """Manifests written before container kinds can't distinguish lists
    from dicts: template-free restore fails loud; a template still
    works."""
    import json

    from repro.checkpoint.manager import restore_tree, save_tree

    tree = {"seq": [jnp.ones((2,)), jnp.zeros((2,))]}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck")
        save_tree(tree, path, 0)
        mpath = os.path.join(path, "manifest.json")
        with open(mpath) as f:
            manifest = json.load(f)
        del manifest["containers"]                    # simulate legacy
        with open(mpath, "w") as f:
            json.dump(manifest, f)
        with pytest.raises(ValueError, match="container-kind"):
            restore_tree(path)
        restored, _, _ = restore_tree(path, template=tree)
        _tree_equal(tree, restored)


def test_quantized_model_checkpoint_roundtrip_and_disk_size():
    """A quantized spikingformer-lm checkpoint restores bitwise with no
    template, and int payloads make the linear stack really ~4x/~8x
    smaller on disk."""
    from repro.checkpoint.manager import (dir_nbytes, restore_tree,
                                          save_tree)

    cfg, params, _, _ = _lm_setup()
    qp = quantize_tree(params, "int8")
    with tempfile.TemporaryDirectory() as d:
        save_tree(qp, os.path.join(d, "q"), 5,
                  extra={"quant": {"dtype": "int8"}})
        restored, _, extra = restore_tree(os.path.join(d, "q"))
        assert extra["quant"]["dtype"] == "int8"
        _tree_equal(qp, restored)
    # disk compression on a pure linear stack (K=256: int8 4K/(K+4),
    # int4 (packed nibbles) 8K/(K+8))
    lin = {f"l{i}": {"w": jax.random.normal(jax.random.PRNGKey(i),
                                            (256, 512), jnp.float32)}
           for i in range(3)}
    with tempfile.TemporaryDirectory() as d:
        save_tree(lin, os.path.join(d, "fp"), 0)
        save_tree(quantize_tree(lin, "int8"), os.path.join(d, "q8"), 0)
        save_tree(quantize_tree(lin, "int4"), os.path.join(d, "q4"), 0)
        fp = dir_nbytes(os.path.join(d, "fp"))
        assert fp / dir_nbytes(os.path.join(d, "q8")) >= 3.8
        assert fp / dir_nbytes(os.path.join(d, "q4")) >= 7.0


# ---------------------------------------------------------------------------
# integration seams: engine config, grad-compress reuse, sharding rules
# ---------------------------------------------------------------------------


def test_engine_weights_selector_validated():
    assert E.EngineConfig(weights="int8").weights == "int8"
    with pytest.raises(ValueError):
        E.EngineConfig(weights="int3")


def test_engine_weights_declaration_enforced_at_dispatch():
    """weights='int8' is a contract: handing spike_linear fp32 params (a
    quantize-at-load step that missed a linear) or the wrong width
    raises; matching params dispatch normally."""
    s = _spikes(jax.random.PRNGKey(0), (8, 16), 0.5)
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 8), jnp.float32)
    q8 = quantize_weight(w, "int8")
    q4 = quantize_weight(w, "int4")
    eng8 = E.EngineConfig(mode="dense", weights="int8")
    out = E.spike_linear(q8, s, engine=eng8)
    assert out.shape == (8, 8)
    with pytest.raises(ValueError, match="declares weights"):
        E.spike_linear({"w": w}, s, engine=eng8)
    with pytest.raises(ValueError, match="declares weights"):
        E.spike_linear(q4, s, engine=eng8)
    # an int4 declaration accepts packed nibbles AND int8-stored codes
    # (the odd-K fallback keeps 4-bit values in int8 dtype)
    eng4 = E.EngineConfig(mode="dense", weights="int4")
    E.spike_linear(q4, s, engine=eng4)
    w_odd = jax.random.normal(jax.random.PRNGKey(2), (15, 8), jnp.float32)
    q4_odd = quantize_weight(w_odd, "int4")
    assert q4_odd["qw"].dtype == jnp.int8
    E.spike_linear(q4_odd, _spikes(jax.random.PRNGKey(3), (8, 15), 0.5),
                   engine=eng4)
    with pytest.raises(ValueError, match="declares weights"):
        E.spike_linear({"w": w}, s, engine=eng4)
    # fp32 declaration (the default) accepts both layouts
    E.spike_linear(q4, s, engine=E.DENSE)
    E.spike_linear({"w": w}, s, engine=E.DENSE)


def test_grad_compress_uses_shared_quantizer():
    """optim.grad_compress is a thin wrapper over the repro.quant core:
    identical scale and codes, round-trip error bounded by scale/2."""
    from repro.optim import int8_compress, int8_decompress
    from repro.quant import dequantize_values, quantize_values

    x = jax.random.normal(jax.random.PRNGKey(0), (64,), jnp.float32) * 3.0
    q, scale = int8_compress(x)
    assert float(scale) == pytest.approx(float(jnp.abs(x).max()) / 127.0)
    np.testing.assert_array_equal(
        np.asarray(q), np.asarray(quantize_values(x, scale, 8)))
    y = int8_decompress(q, scale)
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(dequantize_values(q, scale)))
    assert float(jnp.abs(y - x).max()) <= float(scale) / 2 + 1e-7


def test_quantized_params_get_sharding_specs():
    """parallel/rules.py covers quantized trees: qw shards like w, scales
    ride the output-channel axis."""
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_config
    from repro.parallel import rules
    from repro.parallel.sharding import param_specs

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_config("h2o-danube-3-4b", smoke=True)
    from repro.models import registry
    params = registry.init(cfg, jax.random.PRNGKey(0))
    qp = quantize_tree(params, "int8")
    specs = param_specs(qp, rules.rules_for(cfg, mesh), mesh=mesh)
    wq = specs["layers"]["wq"]
    assert tuple(wq["qw"])[-2:] == ("data", "model")
    assert tuple(wq["scale"])[-1:] == ("model",)
    fp_specs = param_specs(params, rules.rules_for(cfg, mesh), mesh=mesh)
    assert tuple(wq["qw"]) == tuple(fp_specs["layers"]["wq"]["w"])


def test_footprint_report_counts_quantized_leaves():
    cfg, params, _, _ = _lm_setup()
    rep8 = footprint_report(params, quantize_tree(params, "int8"))
    rep4 = footprint_report(params, quantize_tree(params, "int4"))
    # smoke config is fp32 with K in {64, 128, 256}: int8 lands between
    # 3.5x and 4x, int4 between 6x and 8x; whole tree is smaller (embeds)
    assert 3.5 <= rep8["compression"] <= 4.0
    assert 6.0 <= rep4["compression"] <= 8.0
    assert rep8["total_compression"] < rep8["compression"]
