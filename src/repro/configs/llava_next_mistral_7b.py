"""llava-next-mistral-7b [vlm]: mistral-7b backbone (32L d_model=4096 32H
GQA kv=8 d_ff=14336 vocab=32000, SWA 4096) + anyres vision tiling STUB
(input_specs provides precomputed patch embeddings; the mm projector IS
implemented) [hf:llava-hf/llava-v1.6-mistral-7b-hf]."""
from .base import FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000,
    attn_type="swa", window=4096, act="silu", gated=True,
    rope_theta=1_000_000.0,
    frontend=FrontendConfig(kind="vision", num_embeds=2880, embed_dim=1024),
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=96, num_heads=4, num_kv_heads=2, head_dim=24,
    d_ff=192, vocab_size=512, window=16, dtype="float32", remat=False,
    frontend=FrontendConfig(kind="vision", num_embeds=8, embed_dim=32))
