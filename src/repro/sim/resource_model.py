"""FPGA resource model: LUT6 AND-PopCount counting (Fig. 9), decoder /
balancer / engine LUT+DSP breakdowns (Tables V, VI), DSP savings law.

The AND-PopCount counters are *constructive* — they build the actual
compressor netlists column-by-column and count LUT6s and logic depth, so
the paper's "depth 5 -> 2, -52% LUTs at 2x18b" claim is checked by
construction, not hard-coded.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Tuple

# ---------------------------------------------------------------------------
# AND-PopCount: naive (Gao et al. [24]) vs LUT6-optimized (Fig. 9)
# ---------------------------------------------------------------------------


def naive_and_popcount(n_bits: int) -> Tuple[int, int]:
    """Naive: N 2-input ANDs on LUT6s, then a pairwise adder tree of FAs/HAs.

    Returns (lut6_count, depth). An m-bit ripple adder costs m LUT6s
    (carry chain), depth 1 stage per tree level.
    """
    luts = n_bits          # AND stage (one LUT6 per pair, 2/6 inputs used)
    depth = 1
    widths = [1] * n_bits  # operand bit-widths entering the adder tree
    while len(widths) > 1:
        nxt = []
        for i in range(0, len(widths) - 1, 2):
            w = max(widths[i], widths[i + 1])
            luts += w                  # w-bit adder
            nxt.append(w + 1)
        if len(widths) % 2:
            nxt.append(widths[-1])
        widths = nxt
        depth += 1
    return luts, depth


def lut6_and_popcount(n_bits: int) -> Tuple[int, int]:
    """Ours: stage-1 fused AND+count 6:2 compressors (2 LUT6 per 3 pairs),
    then 6:3 compressor stages (3 LUT6 each) until <= 2 rows per column,
    then a carry-propagate adder.

    Returns (lut6_count, depth) with depth = compressor stages (the CPA is
    counted in LUTs but, as in the paper, not as a compressor stage).
    """
    luts = 0
    # stage 1: ceil(N/3) 6:2 compressors -> per compressor a 2-bit count
    n_comp = -(-n_bits // 3)
    luts += 2 * n_comp
    depth = 1
    cols: Dict[int, int] = {0: n_comp, 1: n_comp}  # weight -> #bits
    while max(cols.values()) > 2:
        new_cols: Dict[int, int] = {}
        for w in sorted(cols):
            c = cols[w]
            full = c // 6
            rem = c - 6 * full
            luts += 3 * full
            for _ in range(full):  # 6:3 -> bits at w, w+1, w+2
                for dw in range(3):
                    new_cols[w + dw] = new_cols.get(w + dw, 0) + 1
            # remainder: FAs (3:2, 1 LUT6 dual-output), then passthrough
            while rem >= 3:
                luts += 1
                new_cols[w] = new_cols.get(w, 0) + 1
                new_cols[w + 1] = new_cols.get(w + 1, 0) + 1
                rem -= 3
            new_cols[w] = new_cols.get(w, 0) + rem
        cols = new_cols
        depth += 1
    # final CPA over the remaining two operands
    width = max(cols) + 1
    luts += width
    return luts, depth


def and_popcount_comparison(n_bits: int = 18) -> Dict[str, float]:
    """Fig. 9 headline: for two 18-bit inputs, depth 5 -> 2 and -52% LUTs."""
    nl, nd = naive_and_popcount(n_bits)
    ol, od = lut6_and_popcount(n_bits)
    return {"n_bits": n_bits, "naive_luts": nl, "naive_depth": nd,
            "ours_luts": ol, "ours_depth": od,
            "lut_reduction": 1.0 - ol / nl}


# ---------------------------------------------------------------------------
# Engine-level resource model (Tables V / VI)
# ---------------------------------------------------------------------------

# calibration constants (documented fits to the paper's measured breakdown)
_LUT_DECODER_BASE = 73.0            # per-decoder tracker/one-hot base cost
_LUT_PER_DECODER_BIT_LANE = 0.53    # Eq. 5 carry chain per bit*lane
_LUT_PER_BALANCER_UNIT = 16.4       # extraction mux per grid point per G
_NEURON_LUTS = 2200                 # P_Fx x P_Ts membrane update grid
_BINARY_CONTROL_LUTS = 2600         # implicit-transpose + accum control
_DENSE_DSPS = 1024                  # 4-lane DSP48E2s for the dense baseline


@dataclass(frozen=True)
class HardwareConfig:
    """FireFly-T's evaluated configuration (§V-D)."""
    p_tsfx: int = 8       # P_Ts * P_Fx
    p_ci: int = 16
    p_co: int = 64
    g: int = 4            # decoder throughput per grid point
    p_wo: int = 2
    # binary engine: Table V's 16 DSPs = P_Bm*P_Bn/4 => 64 PEs; Eq. 4 sizing
    # for Spikingformer-8-512 gives P_b ~= 2k => P_Bk = 32
    p_bm: int = 8
    p_bn: int = 8
    p_bk: int = 32
    freq_mhz: float = 300.0

    @property
    def m_lanes(self) -> int:
        return self.g // self.p_wo

    @property
    def peak_dense_gops(self) -> float:
        return 2.0 * self.p_tsfx * self.p_ci * self.p_co * \
            self.freq_mhz * 1e6 / 1e9


def decoder_luts(hw: HardwareConfig) -> int:
    n_decoders = hw.p_wo * hw.p_tsfx
    per_dec = _LUT_DECODER_BASE + \
        _LUT_PER_DECODER_BIT_LANE * hw.p_ci * hw.m_lanes
    return int(per_dec * n_decoders)


def balancer_luts(hw: HardwareConfig) -> int:
    return int(_LUT_PER_BALANCER_UNIT * hw.g * hw.p_co * hw.p_tsfx)


def sparse_engine_dsps(hw: HardwareConfig) -> int:
    """DSP law: dense count scaled by G / P_Ci (the paper's 1 - G/P_Ci
    saving), plus the pipelined-accumulation extras at G=4."""
    base = _DENSE_DSPS * hw.g // hw.p_ci
    extra = 32 if hw.g >= 4 else 0
    return base + extra


def binary_engine_luts(hw: HardwareConfig) -> int:
    per_pe, _ = lut6_and_popcount(hw.p_bk)
    return int(hw.p_bm * hw.p_bn * per_pe) + _BINARY_CONTROL_LUTS


def binary_engine_dsps(hw: HardwareConfig) -> int:
    return hw.p_bm * hw.p_bn // 4  # 4-lane accumulation (§III-C)


def resource_breakdown(hw: HardwareConfig) -> Dict[str, Dict[str, float]]:
    """Table V/VI-style breakdown (LUTs modeled; paper-measured values are
    reported alongside in benchmarks/table56_resources.py)."""
    dec = decoder_luts(hw)
    bal = balancer_luts(hw)
    neuron = _NEURON_LUTS
    others = int(0.07 * (dec + bal + neuron))
    sparse_luts = dec + bal + neuron + others
    return {
        "sparse_engine": {"kluts": sparse_luts / 1e3,
                          "dsps": sparse_engine_dsps(hw),
                          "decoder_luts": dec, "balancer_luts": bal,
                          "neuron_luts": neuron, "other_luts": others},
        "binary_engine": {"kluts": binary_engine_luts(hw) / 1e3,
                          "dsps": binary_engine_dsps(hw)},
        "orchestrator": {"kluts": 1.2, "dsps": 0},
    }


def dsp_savings(hw: HardwareConfig) -> Dict[str, float]:
    """The sparsity-support trade (§V-D): DSPs saved vs logic added."""
    saved = _DENSE_DSPS - _DENSE_DSPS * hw.g // hw.p_ci
    lut_equiv = saved * 86  # paper's conversion: 1 DSP ~ 86 LUTs [40]
    overhead = decoder_luts(hw) + balancer_luts(hw)
    return {"dsps_saved": saved, "lut_equivalent": lut_equiv,
            "sparsity_logic_luts": overhead,
            "net_win_luts": lut_equiv - overhead}
