"""Serving orchestrator: continuous batching with per-slot state, chunked
prefill, and an optional device mesh.

The paper's third pillar — the orchestrator that "dynamically manipulates
input dataflows" and load-balances heterogeneous work across parallel
units — mapped to the TPU serve path (DESIGN.md §Orchestrator):

  * per-slot state: every cache slot carries its own timeline (positions
    ``pos: (B,)``, validity tags ``(n_layers, B, s)``), so a finished
    sequence frees its slot and a queued request claims it mid-flight —
    the freed slot's tags are invalidated at admission, the new request
    decodes from position 0 and can never attend over the dead request's
    stale K/V;
  * chunked prefill: a prompt fills its slot's cache in ``chunk``-sized
    bites through the same decode step the generating slots ride (their
    rows are padding-masked via ``n_tok``), with the chunk width chosen
    per wave by the popcount-aware load-balance policy lifted from
    ``sim/decoder_sim.py``'s input-tracker model (:func:`choose_chunk`);
  * mesh-sharded decode: given a ``jax.sharding.Mesh``, slots shard over
    the 'data' axis and heads/vocab over 'model' using the existing
    ``parallel/sharding.py`` + ``parallel/rules.py`` tables — the same
    NamedSharding machinery launch/dryrun.py exercises at training scale;
  * greedy sampling (argmax) for determinism;
  * spiking LMs (``--arch spikingformer-lm``) decode against a
    *bit-packed* spike KV cache (uint32 words, AND-PopCount scoring —
    the paper's 32x spike-RAM compression); the server reports the
    measured cache footprint vs the unpacked layout;
  * quantized weights: ``--quantize int8|int4`` quantizes the param tree
    at load (repro.quant: symmetric per-output-channel scales, packed
    nibbles for int4) — the other half of the paper's dual-side
    compression. Every linear then serves integer codes (the decode
    path's analog matmuls dequantize through the epilogue scale; spike
    matmuls take the int8-accumulating kernel when the engine goes
    sparse) and the server reports the measured weight footprint next to
    the KV-cache report.
"""
from __future__ import annotations

import argparse
import functools
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ALL_ARCHS, get_config
from repro.configs.base import RunShape
from repro.launch import steps as steps_lib
from repro.models import registry
from repro.parallel import rules as prules
from repro.parallel.sharding import (fit_spec_to_shape, rules_for_mesh,
                                     shard_put, use_rules)
from repro.sim import decoder_sim


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (L,) int32
    max_new_tokens: int
    generated: List[int] = field(default_factory=list)
    # full logits row behind every sampled token (server trace_logits=True)
    logit_trace: List[np.ndarray] = field(default_factory=list)
    done: bool = False


def choose_chunk(remaining_prompt: int, n_decoding: int, max_chunk: int,
                 *, lanes: int = 4) -> int:
    """Prefill chunk width by the paper's Eq. 6 composite metric, driven
    by ``sim/decoder_sim.py``'s input-tracker model.

    Mapping: the prefill backlog of R tokens split into C-token bites is a
    stream of P_Ci = C-bit input words; the batched step is one worker
    whose decoder consumes a word in ``max(1, ceil(popcount / M))`` cycles
    (the input-tracker occupancy rule). The lane budget M is the per-wave
    useful-token throughput: ``lanes`` per prefilling slot, scaled by the
    decode riders — every generating slot contributes one useful token to
    each wave, so the marginal padding cost of a wider bite shrinks as
    the decode share grows. That is exactly Fig. 12's ``P_Ci_opt ~=
    G / (1 - sparsity)`` with sparsity = the decode share of the batch.
    F = 1 / (P_Ci * D^2) (Eq. 6, lambda folded out — it rescales, never
    reorders); argmax over power-of-two candidates.
    """
    if remaining_prompt <= 0 or max_chunk <= 1:
        return 1
    g_eff = lanes * (1 + n_decoding)
    best_c, best_f = 1, -1.0
    c = 1
    while c <= max_chunk:
        d = _drain_latency(remaining_prompt, c, g_eff)
        f = 1.0 / (c * float(d) * float(d))
        if f > best_f:
            best_c, best_f = c, f
        c *= 2
    return best_c


@functools.lru_cache(maxsize=65536)
def _drain_latency(remaining: int, chunk: int, g_eff: int) -> int:
    """Simulated drain latency of the bite stream (memoized: the policy
    runs on the serving hot loop's host side, and the backlog walks the
    same (remaining, chunk) grid wave after wave)."""
    n_full, rem = divmod(remaining, chunk)
    pc = np.full(n_full + (1 if rem else 0), chunk, np.int64)
    if rem:
        pc[-1] = rem
    dcfg = decoder_sim.DecoderConfig(p_ci=chunk, m_lanes=g_eff, p_wo=1)
    return decoder_sim.simulate_latency(pc, dcfg)


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


class BatchedServer:
    """Slot-based continuous batching over a fixed cache batch size.

    ``chunk``: prefill bite width; 0 = auto (:func:`choose_chunk` per
    wave). Wave widths are rounded up to powers of two so the jitted step
    compiles O(log max_chunk) distinct shapes, not one per width.
    ``mesh``: optional ``jax.sharding.Mesh`` with ('data', 'model') axes —
    params, cache, and the step's inputs/outputs get NamedShardings from
    the ``parallel/rules.py`` tables (slots on 'data', heads/vocab on
    'model').
    """

    def __init__(self, cfg, params, slots: int, max_len: int, *,
                 chunk: int = 0, mesh=None, trace_logits: bool = False):
        if not registry.supports_slots(cfg):
            raise ValueError(
                f"{cfg.name} ({cfg.family}) has no per-slot decode state; "
                f"continuous batching needs a slotted-decode family "
                f"({sorted(registry.SLOTTED_DECODE)})")
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        # a chunk wider than the rolling window would overwrite its own
        # bite inside one scatter; cap at the window for banded caches
        cap = max_len if cfg.attn_type == "full" else min(max_len,
                                                          cfg.window)
        self.max_chunk = max(1, min(chunk if chunk > 0 else cap, cap))
        self.fixed_chunk = chunk > 0
        self.mesh = mesh
        self.trace_logits = trace_logits
        self.params = params
        # window rings get chunk-1 slots of headroom so a prefill bite's
        # write-before-attend scatter never evicts a live-window entry
        self.headroom = 0 if cfg.attn_type == "full" else self.max_chunk - 1
        self.cache = registry.init_cache(cfg, slots, max_len,
                                         chunk_headroom=self.headroom)
        self._build_step()
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.slot_pos = np.zeros(slots, np.int64)
        self.queue: List[Request] = []
        self.completed: List[Request] = []
        self.waves = 0

    # -- compiled steps ----------------------------------------------------

    def _build_step(self):
        cfg = self.cfg
        step = steps_lib.build_batched_serve_step(cfg)
        if self.mesh is None:
            self._rules = None
            self._step = jax.jit(step, donate_argnums=(1,))
            self._invalidate = jax.jit(
                lambda cache, mask: registry.invalidate_slots(cfg, cache,
                                                              mask),
                donate_argnums=(0,))
            return
        mesh = self.mesh
        self._rules = rules_for_mesh(mesh)
        shape = RunShape("serve", self.max_len, self.slots, "decode")
        pspecs = prules.params_partition(cfg, self.params, mesh)
        cache_abs = jax.eval_shape(
            lambda: registry.init_cache(cfg, self.slots, self.max_len,
                                        chunk_headroom=self.headroom))
        cspecs = prules.cache_partition(cfg, shape, mesh, cache_abs)
        pshard = prules.tree_shardings(pspecs, mesh)
        cshard = prules.tree_shardings(cspecs, mesh)
        dp = prules.dp_part(prules.batch_axes(shape, mesh))
        tok_spec = fit_spec_to_shape(P(dp, None), (self.slots, 1), mesh)
        vec_spec = fit_spec_to_shape(P(dp), (self.slots,), mesh)
        logits_spec = fit_spec_to_shape(
            P(dp, None, "model"), (self.slots, 1, cfg.vocab_size), mesh)
        rules = self._rules

        def step_with_rules(params, cache, tokens, pos, n_tok):
            with use_rules(rules):      # ambient only during tracing
                return step(params, cache, tokens, pos, n_tok)

        self._step = jax.jit(
            step_with_rules,
            in_shardings=(pshard, cshard, NamedSharding(mesh, tok_spec),
                          NamedSharding(mesh, vec_spec),
                          NamedSharding(mesh, vec_spec)),
            out_shardings=(NamedSharding(mesh, logits_spec), cshard),
            donate_argnums=(1,))
        self._invalidate = jax.jit(
            lambda cache, mask: registry.invalidate_slots(cfg, cache,
                                                          mask),
            in_shardings=(cshard, NamedSharding(mesh, P())),
            out_shardings=cshard, donate_argnums=(0,))
        self.params = shard_put(self.params, pspecs, mesh)
        self.cache = shard_put(self.cache, cspecs, mesh)

    # -- stats -------------------------------------------------------------

    def kv_cache_stats(self) -> Dict[str, float]:
        """Measured KV footprint; 'compression' is the ratio vs storing
        the same entries unpacked in the activation dtype (32x per word
        when the spiking packed-KV path is on, 1.0 otherwise). Leaves are
        selected by key (k/v payloads vs pos tags), not dtype sniffing."""
        flat, _ = jax.tree_util.tree_flatten_with_path(self.cache)
        kv = [l for path, l in flat
              if getattr(path[-1], "key", None) in ("k", "v")]
        kv_bytes = sum(l.nbytes for l in kv)
        act_bytes = jnp.dtype(self.cfg.dtype).itemsize
        packed = any(l.dtype == jnp.uint32 for l in kv)
        if packed:
            words = -(-self.cfg.head_dim // 32)
            unpacked = kv_bytes // 4 // words * self.cfg.head_dim * act_bytes
        else:
            unpacked = kv_bytes
        return {"kv_bytes": kv_bytes, "packed": packed,
                "compression": unpacked / max(1, kv_bytes)}

    # -- scheduling --------------------------------------------------------

    def submit(self, req: Request):
        if len(req.prompt) == 0:
            raise ValueError(f"request {req.rid}: empty prompt")
        if len(req.prompt) > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt length {len(req.prompt)} "
                f"exceeds cache capacity max_len={self.max_len}")
        if req.max_new_tokens < 1:
            raise ValueError(f"request {req.rid}: max_new_tokens must be "
                             f">= 1, got {req.max_new_tokens}")
        self.queue.append(req)

    def _admit(self):
        fresh = np.zeros(self.slots, bool)
        for s in range(self.slots):
            if self.slot_req[s] is None and self.queue:
                self.slot_req[s] = self.queue.pop(0)
                self.slot_pos[s] = 0
                fresh[s] = True
        if fresh.any():
            # the freed slots' validity tags go to -1: the new occupants
            # start at position 0 with an empty visible cache (this is the
            # slot-reuse bug fix — without it a re-admitted slot attends
            # over the previous request's stale K/V)
            self.cache = self._invalidate(self.cache, jnp.asarray(fresh))

    def step(self) -> bool:
        """One orchestrator wave: admit queued requests into free slots,
        issue a chunked-prefill bite or one decode token per active slot,
        run the batched step, sample, retire finished sequences."""
        self._admit()
        active = [s for s in range(self.slots) if self.slot_req[s]]
        if not active:
            return False
        backlog = sum(max(0, len(self.slot_req[s].prompt)
                          - self.slot_pos[s]) for s in active)
        n_decoding = sum(self.slot_pos[s] >= len(self.slot_req[s].prompt)
                         for s in active)
        chunk = self.max_chunk if self.fixed_chunk else \
            choose_chunk(backlog, n_decoding, self.max_chunk)
        n_tok = np.zeros(self.slots, np.int32)
        for s in active:
            req, p = self.slot_req[s], int(self.slot_pos[s])
            if p < len(req.prompt):
                n_tok[s] = min(chunk, len(req.prompt) - p,
                               self.max_len - p)
            else:
                n_tok[s] = 1
        width = _next_pow2(int(n_tok.max()))
        tokens = np.zeros((self.slots, width), np.int32)
        for s in active:
            req, p, n = self.slot_req[s], int(self.slot_pos[s]), int(n_tok[s])
            if p < len(req.prompt):
                tokens[s, :n] = req.prompt[p:p + n]
            else:
                # the wave that finishes a prompt always samples the first
                # generated token, so a decoding slot is never empty here
                tokens[s, 0] = req.generated[-1]
        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(self.slot_pos, jnp.int32), jnp.asarray(n_tok))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))    # (slots, width)
        for s in active:
            req, n = self.slot_req[s], int(n_tok[s])
            self.slot_pos[s] += n
            p = int(self.slot_pos[s])
            if p >= len(req.prompt):
                req.generated.append(int(nxt[s, n - 1]))
                if self.trace_logits:
                    req.logit_trace.append(np.asarray(logits[s, n - 1]))
            # retire when generation quota is met or the cache is full:
            # position max_len - 1 is the last usable entry, and the token
            # sampled from it is still kept (it just can't be fed back)
            if len(req.generated) >= req.max_new_tokens or \
                    p >= self.max_len:
                req.done = True
                self.completed.append(req)
                self.slot_req[s] = None
        self.waves += 1
        return True

    def run(self) -> int:
        """Drain the queue; returns the total wave count (self.waves)."""
        while self.step():
            pass
        return self.waves


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-3-4b",
                    choices=list(ALL_ARCHS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--chunk", type=int, default=0,
                    help="prefill chunk width; 0 = popcount-aware policy")
    ap.add_argument("--mesh", default="",
                    help="DATAxMODEL serving mesh, e.g. 2x2 (needs that "
                         "many devices; '' = unsharded)")
    ap.add_argument("--quantize", default="none",
                    choices=["none", "int8", "int4"],
                    help="quantize linear weights at load (repro.quant); "
                         "reports the measured footprint compression")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    if not registry.has_decode(cfg):
        raise SystemExit(f"{args.arch} has no decode step")
    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_serve_mesh
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_serve_mesh(d, m)
    params = registry.init(cfg, jax.random.PRNGKey(0))
    wrep = None
    if args.quantize != "none":
        from repro.core.engine import EngineConfig
        from repro.quant import footprint_report, quantize_tree
        qparams = quantize_tree(params, args.quantize)
        wrep = footprint_report(params, qparams)
        # declare the weight datapath on the engine (the per-call dispatch
        # keys off the quantized param dicts; this records intent and lets
        # 'auto' matmul routing stay in effect for the spike call sites)
        eng = cfg.engine if cfg.engine is not None else EngineConfig()
        cfg = cfg.replace(engine=eng.replace(weights=args.quantize))
        params = qparams
    server = BatchedServer(cfg, params, args.slots, args.max_len,
                           chunk=args.chunk, mesh=mesh)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        server.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                       args.prompt_len).astype(np.int32),
            max_new_tokens=args.max_new))
    kv = server.kv_cache_stats()
    print(f"[serve] kv cache {kv['kv_bytes']/1024:.1f} KiB "
          f"(packed={kv['packed']}, {kv['compression']:.0f}x vs unpacked)"
          + (f", mesh={args.mesh}" if mesh is not None else ""))
    if wrep is not None:
        print(f"[serve] weights {wrep['quant_weight_bytes']/1024:.1f} KiB "
              f"({args.quantize}): {wrep['compression']:.2f}x vs "
              f"{jnp.dtype(cfg.dtype).name} linears "
              f"({wrep['total_compression']:.2f}x whole tree)")
    t0 = time.time()
    steps = server.run()
    dt = time.time() - t0
    n_gen = sum(len(r.generated) for r in server.completed)
    n_pre = sum(len(r.prompt) for r in server.completed)
    print(f"[serve] {len(server.completed)} requests, {n_gen} generated "
          f"(+{n_pre} prompt) tokens, {steps} waves in {dt:.2f}s "
          f"({(n_gen + n_pre)/dt:.1f} tok/s on CPU smoke config)")
    for r in server.completed[:3]:
        print(f"  req {r.rid}: {r.generated}")


if __name__ == "__main__":
    main()
