from .calibrate import calibrate, logit_delta
from .qat import fake_quant, fake_quant_tree
from .quantize import (dequantize_tree, dequantize_values,
                       dequantize_weight, footprint_report, is_quantized,
                       pack_int4, quantize_tree, quantize_values,
                       quantize_weight, symmetric_scale, tree_nbytes,
                       unpack_int4, weight_bits)
