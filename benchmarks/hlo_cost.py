"""Trip-count-aware HLO cost extraction.

XLA's ``HloCostAnalysis`` (what ``compiled.cost_analysis()`` reports)
visits each ``while`` body ONCE — for scan-over-layers models that
undercounts FLOPs by ~num_layers x. This parser walks the optimized HLO
text, builds the computation call graph (while bodies x known_trip_count,
fusions, calls, conditionals) and accumulates:

  * flops            — dot ops: 2 * prod(out dims) * K (contraction size
                       from the lhs operand's definition);
  * bytes            — sum of produced-value bytes (excluding free views:
                       bitcast/GTE/tuple/parameter/constant), x2 for the
                       write+read round trip — an HBM-traffic proxy;
  * collectives      — result bytes per collective kind (all-gather,
                       all-reduce, reduce-scatter, all-to-all,
                       collective-permute), trip-multiplied.

All quantities are PER DEVICE (the HLO is the post-GSPMD partitioned
module). Validated against analytic 6*N*D in tests/test_roofline.py.
"""
from __future__ import annotations

import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
                "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2,
                "f32": 4, "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1,
                "f8e5m2": 1, "u4": 1}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_FREE_OPS = {"bitcast", "get-tuple-element", "tuple", "parameter",
             "constant", "iota", "copy-start", "copy-done",
             "after-all", "partition-id"}

_SHAPE_RE = re.compile(r"^(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*.*\)\s*->.*\{\s*$")
_CALL_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count"\s*:\s*\{\s*"n"\s*:\s*"(\d+)"')
_DOT_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_DOT_RHS_CDIMS_RE = re.compile(r"rhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")


def _operand_names(operand_text: str) -> List[str]:
    """Operand instruction names from an HLO operand list.

    Operand lists look like ``f32[32,128]{1,0} %Arg_0.1, f32[128,64]{1,0}
    %Arg_1.2`` — the shape strings contain commas, so splitting on ','
    mangles the names (the seed bug that zeroed every dot's contraction
    dim). Each operand reference is the ``%name`` token, so pull those."""
    return _OPERAND_NAME_RE.findall(operand_text)


def _parse_instr(line: str) -> Optional[Tuple[str, str, str]]:
    """'  ROOT %x = TYPE op(...)...' -> (name, type_str, opcode).

    Handles tuple types with nested parens/layouts/comments (regexes
    can't — tuple types contain '/*index=5*/' and '{...}' freely)."""
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[:eq].lstrip("%")
    rest = s[eq + 3:]
    if rest.startswith("("):
        depth = 0
        end = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        type_str = rest[:end + 1]
        rest2 = rest[end + 1:].strip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str = rest[:sp]
        rest2 = rest[sp + 1:].strip()
    par = rest2.find("(")
    if par < 0:
        return None
    op = rest2[:par].strip()
    if not re.fullmatch(r"[\w\-]+", op):
        return None
    return name, type_str, op


def _parse_type(t: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.match(t)
    if not m:
        return None
    dt = m.group(1)
    if dt not in _DTYPE_BYTES:
        return None
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return dt, dims


def _nbytes(t: str) -> int:
    """Bytes of a type string; tuples sum their array components."""
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", t):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


class HloCost:
    def __init__(self, text: str):
        self.comps: Dict[str, List[str]] = {}
        self.entry: Optional[str] = None
        self._split(text)
        self._memo: Dict[str, tuple] = {}

    def _split(self, text: str):
        cur, name = None, None
        for line in text.splitlines():
            if not line.startswith(" ") and line.rstrip().endswith("{"):
                m = _COMP_RE.match(line.strip())
                if m:
                    name = m.group(2)
                    cur = []
                    self.comps[name] = cur
                    if m.group(1):
                        self.entry = name
                    continue
            if line.startswith("}"):
                cur = None
                continue
            if cur is not None:
                cur.append(line)

    # -- per-computation local costs ------------------------------------
    def _local(self, name: str):
        flops = 0.0
        bytes_ = 0.0       # every produced value (pessimistic proxy)
        hbm = 0.0          # fusion-realistic HBM traffic (see below)
        coll = {k: 0.0 for k in COLLECTIVE_OPS}
        coll_n = {k: 0 for k in COLLECTIVE_OPS}
        calls: List[Tuple[str, int, bool]] = []
        shapes: Dict[str, str] = {}
        opcodes: Dict[str, str] = {}
        lines = self.comps.get(name, [])
        for line in lines:
            m = _parse_instr(line)
            if not m:
                continue
            shapes[m[0]] = m[1]
            opcodes[m[0]] = m[2]

        def _upcast(nm: str) -> bool:
            # XLA CPU legalizes bf16 dots via hoisted bf16->f32 converts
            # ('%convert*' instructions/fusions); TPU consumes bf16
            # natively, so convert-fed dot traffic counts at bf16.
            return nm.startswith("convert") and "f32" in shapes.get(nm, "")

        for line in lines:
            m = _parse_instr(line)
            if not m:
                continue
            iname, itype, op = m
            if op not in _FREE_OPS:
                bytes_ += _nbytes(itype)
            # fusion-realistic HBM model: elementwise/broadcast/reduce
            # chains fuse into their MXU/copy consumers on TPU; what hits
            # HBM is matmul operands+outputs, data movement, cache
            # updates, collectives, and while-loop carries.
            if op in ("dot", "convolution"):
                om = re.search(op + r"\(([^)]*)\)", line)
                any_up = False
                opb = 0.0
                if om:
                    for nm2 in _operand_names(om.group(1)):
                        t = shapes.get(nm2)
                        if not t:
                            continue
                        b2 = _nbytes(t)
                        if _upcast(nm2):
                            b2 //= 2
                            any_up = True
                        opb += b2
                ob = _nbytes(itype)
                if any_up and "f32" in itype:
                    ob //= 2  # result truncated back to bf16 on TPU
                hbm += ob + opb
            elif op in ("dynamic-slice", "dynamic-update-slice", "gather",
                        "scatter", "concatenate", "copy", "transpose",
                        "sort", "pad", "slice"):
                hbm += 2.0 * _nbytes(itype)
            elif any(op == k or op.startswith(k + "-")
                     for k in COLLECTIVE_OPS):
                hbm += 2.0 * _nbytes(itype)
            elif op == "while":
                # true loop carries are read+written from HBM every
                # iteration (this is what makes per-token recurrent scans
                # memory-catastrophic). Scan xs/ys are aliased stacked
                # buffers, NOT carried traffic — heuristic: tuple elements
                # whose leading dim equals the trip count are xs/ys and
                # are excluded (their per-iter slices are counted via the
                # body's dynamic-slice/DUS ops).
                tm = _TRIP_RE.search(line)
                trip = int(tm.group(1)) if tm else 1
                carry = 0
                for em in re.finditer(r"(\w+)\[([\d,]*)\]", itype):
                    dt, dims = em.group(1), em.group(2)
                    if dt not in _DTYPE_BYTES:
                        continue
                    dl = [int(d) for d in dims.split(",")] if dims else []
                    if trip > 1 and dl and dl[0] == trip:
                        continue  # stacked xs/ys buffer
                    n = 1
                    for d in dl:
                        n *= d
                    carry += n * _DTYPE_BYTES[dt]
                hbm += 2.0 * carry * trip
            if op == "dot":
                out = _parse_type(itype)
                ops_m = re.search(r"dot\(([^)]*)\)", line)
                cdims = _DOT_CDIMS_RE.search(line)
                if out and ops_m and cdims:
                    names = _operand_names(ops_m.group(1))
                    k = 1
                    lhs = _parse_type(shapes.get(names[0], "")) if names \
                        else None
                    if lhs and cdims.group(1):
                        for d in cdims.group(1).split(","):
                            k *= lhs[1][int(d)]
                    elif len(names) > 1:
                        # lhs defined out of scope: recover K from the rhs
                        rhs = _parse_type(shapes.get(names[1], ""))
                        rdims = _DOT_RHS_CDIMS_RE.search(line)
                        if rhs and rdims and rdims.group(1):
                            for d in rdims.group(1).split(","):
                                k *= rhs[1][int(d)]
                    nout = 1
                    for d in out[1]:
                        nout *= d
                    flops += 2.0 * nout * k
            elif op == "convolution":
                out = _parse_type(itype)
                if out:
                    nout = 1
                    for d in out[1]:
                        nout *= d
                    km = re.search(r"dim_labels=\S+", line)
                    # approximate: 2 * out * (kernel spatial * in_ch) -- we
                    # recover in_ch*kh*kw from operand 1's definition
                    ops_m = re.search(r"convolution\(([^)]*)\)", line)
                    k = 1
                    if ops_m:
                        names = _operand_names(ops_m.group(1))
                        rhs = _parse_type(shapes.get(names[1], "")) \
                            if len(names) > 1 else None
                        if rhs:
                            k = 1
                            for d in rhs[1][:-1]:
                                k *= d
                    flops += 2.0 * nout * k
            for kind in COLLECTIVE_OPS:
                if op == kind or op.startswith(kind + "-"):
                    b = _nbytes(itype)
                    # XLA's *CPU* pipeline promotes bf16 reductions to f32
                    # (to_apply=%add..._promo) and legalizes bf16 dots via
                    # hoisted converts (operand = %convert_*_fusion) — on
                    # TPU both run natively in bf16. Count such
                    # collectives at the source dtype (0.5x).
                    if "f32[" in itype:
                        opnd = re.search(op + r"[\w\-]*\(%?([\w.\-]+)",
                                         line)
                        src_conv = bool(opnd) and \
                            shapes.get(opnd.group(1)) is not None and \
                            opnd.group(1).startswith("convert")
                        if "promo" in line or src_conv:
                            b = b // 2
                    coll[kind] += b
                    coll_n[kind] += 1
                    break
            if op == "while":
                trip = 1
                tm = _TRIP_RE.search(line)
                if tm:
                    trip = int(tm.group(1))
                cm = _CALL_RE.search(line)
                if cm:
                    calls.append((cm.group(1), trip, True))
            elif op in ("fusion", "call", "custom-call", "reduce",
                        "reduce-window", "scatter", "select-and-scatter",
                        "map", "sort", "all-reduce"):
                # fusion internals never hit HBM: count their flops and
                # collectives but not their intermediate bytes (the fusion
                # instruction's own output bytes are counted above).
                count_bytes = op != "fusion"
                for cm in _CALL_RE.finditer(line):
                    calls.append((cm.group(1), 1, count_bytes))
            elif op == "conditional":
                bm = _COND_BRANCHES_RE.search(line)
                if bm:
                    for b in bm.group(1).split(","):
                        calls.append((b.strip().lstrip("%"), 1, True))
        return flops, bytes_, hbm, coll, coll_n, calls

    def cost(self, name: Optional[str] = None):
        name = name or self.entry
        if name in self._memo:
            return self._memo[name]
        if name not in self.comps:
            out = (0.0, 0.0, 0.0, {k: 0.0 for k in COLLECTIVE_OPS},
                   {k: 0 for k in COLLECTIVE_OPS})
            self._memo[name] = out
            return out
        self._memo[name] = (0.0, 0.0, 0.0,
                            {k: 0.0 for k in COLLECTIVE_OPS},
                            {k: 0 for k in COLLECTIVE_OPS})  # cycle guard
        flops, bytes_, hbm, coll, coll_n, calls = self._local(name)
        for callee, mult, count_bytes in calls:
            cf, cb, ch, cc, cn = self.cost(callee)
            flops += mult * cf
            if count_bytes:
                bytes_ += mult * cb
                hbm += mult * ch
            for k in COLLECTIVE_OPS:
                coll[k] += mult * cc[k]
                coll_n[k] += mult * cn[k]
        self._memo[name] = (flops, bytes_, hbm, coll, coll_n)
        return self._memo[name]


def analyze_file(path: str) -> Dict:
    with open(path) as f:
        text = f.read()
    hc = HloCost(text)
    flops, bytes_, hbm, coll, coll_n = hc.cost()
    return {"flops": flops, "bytes_upper": 2.0 * bytes_,  # every value rw
            "hbm_bytes": hbm,  # fusion-realistic HBM traffic
            "collective_bytes": coll, "collective_counts": coll_n}


# ---------------------------------------------------------------------------
# attribution: per-(op, shape, source) cost breakdown with trip multipliers
# ---------------------------------------------------------------------------

_OPNAME_RE = re.compile(r'op_name="([^"]*)"')


def attribution(path: str, kind: str = "collective", top: int = 12):
    """Top contributors to a cost term, trip-multiplied.

    kind='collective' -> (GB, 'op type shape', jax op_name tail)
    kind='hbm'        -> same for the fusion-realistic memory model
    """
    with open(path) as f:
        hc = HloCost(f.read())
    mult = {hc.entry: 1.0}
    order, seen, i = [hc.entry], {hc.entry}, 0
    while i < len(order):
        comp = order[i]
        i += 1
        _, _, _, _, _, calls = hc._local(comp)
        for callee, m, _ in calls:
            mult[callee] = mult.get(callee, 0.0) + mult[comp] * m
            if callee not in seen:
                seen.add(callee)
                order.append(callee)
    # fusion bodies' internals never hit HBM (mirrors cost()'s
    # count_bytes=False): attribute only non-fusion computations.
    fusion_callees = set()
    for comp, lines in hc.comps.items():
        for line in lines:
            m = _parse_instr(line)
            if m and m[2] == "fusion":
                for cm in _CALL_RE.finditer(line):
                    fusion_callees.add(cm.group(1))
    out: Dict[str, float] = {}
    mem_ops = {"dot", "convolution", "dynamic-slice", "dynamic-update-slice",
               "gather", "scatter", "concatenate", "copy", "transpose",
               "sort", "pad", "slice"}
    for comp, lines in hc.comps.items():
        if comp not in mult or comp in fusion_callees:
            continue
        shapes = {}
        for line in lines:
            m = _parse_instr(line)
            if m:
                shapes[m[0]] = m[1]
        for line in lines:
            m = _parse_instr(line)
            if not m:
                continue
            _, itype, op = m
            is_coll = any(op == k or op.startswith(k + "-")
                          for k in COLLECTIVE_OPS)
            if kind == "collective" and not is_coll:
                continue
            if kind == "hbm" and not (op in mem_ops or is_coll):
                continue
            b = _nbytes(itype)
            if kind == "hbm" and op == "dot":
                om = re.search(r"dot\(([^)]*)\)", line)
                if om:
                    for nm in _operand_names(om.group(1)):
                        t = shapes.get(nm)
                        if t:
                            b += _nbytes(t)
            nm = _OPNAME_RE.search(line)
            src = nm.group(1).split("/")[-1][:40] if nm else "?"
            key = f"{op} {itype[:36]} <{src}>"
            out[key] = out.get(key, 0.0) + b * mult[comp]
    return sorted(out.items(), key=lambda kv: -kv[1])[:top]
