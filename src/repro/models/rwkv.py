"""RWKV6 "Finch" (attention-free, data-dependent decay) — rwkv6-3b.

Faithful to arXiv:2404.05892: data-dependent token-shift (ddlerp via
low-rank adapters over 5 mix targets r/k/v/w/g), per-channel data-dependent
decay ``w = exp(-exp(w0 + lora_w(x)))``, per-head matrix-valued state
``S ∈ R^{n x n}`` with bonus ``u``, grouped head-norm, squared-ReLU channel
mix. Sequence recurrence is a ``lax.scan`` (the chunkwise-parallel form is a
§Perf hillclimb — see EXPERIMENTS.md).

FireFly-T applicability: attention-free ⇒ the binary engine does NOT apply
(DESIGN.md §5); implemented without the technique per the assignment.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel.sharding import constrain
from . import nn

N_MIX = 5  # r, k, v, w, g


def _layer_init(key, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    r = cfg.rwkv
    d = cfg.d_model
    n = r.head_size
    h = d // n
    ks = jax.random.split(key, 12)
    std = 1.0 / math.sqrt(d)
    tm = {
        "mu_x": jnp.zeros((d,), dt),
        "mu": nn.normal(ks[0], (N_MIX, d), 0.02, dt),
        "A_mix": nn.normal(ks[1], (d, N_MIX * r.lora_mix), std, dt),
        "B_mix": nn.normal(ks[2], (N_MIX, r.lora_mix, d), 0.02, dt),
        "w0": nn.normal(ks[3], (d,), 0.5, jnp.float32) - 5.0,
        "A_w": nn.normal(ks[4], (d, r.lora_decay), std, dt),
        "B_w": nn.normal(ks[5], (r.lora_decay, d), 0.02, jnp.float32),
        "wr": nn.linear_init(ks[6], d, d, dtype=dt),
        "wk": nn.linear_init(ks[7], d, d, dtype=dt),
        "wv": nn.linear_init(ks[8], d, d, dtype=dt),
        "wg": nn.linear_init(ks[9], d, d, dtype=dt),
        "wo": nn.linear_init(ks[10], d, d,
                             std=std / math.sqrt(2 * cfg.num_layers), dtype=dt),
        "u": nn.normal(ks[11], (h, n), 0.02, jnp.float32),
        "ln_x": nn.layernorm_init(d, dt),
    }
    kc = jax.random.split(ks[11], 4)
    cm = {
        "mu_k": jnp.full((d,), 0.5, dt),
        "mu_r": jnp.full((d,), 0.5, dt),
        "wk": nn.linear_init(kc[0], d, cfg.d_ff, dtype=dt),
        "wv": nn.linear_init(kc[1], cfg.d_ff, d, dtype=dt),
        "wr": nn.linear_init(kc[2], d, d, dtype=dt),
    }
    return {"ln1": nn.layernorm_init(d, dt), "tm": tm,
            "ln2": nn.layernorm_init(d, dt), "cm": cm}


def init(cfg: ModelConfig, key) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.dtype)
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    keys = jax.random.split(k_layers, cfg.num_layers)
    return {
        "embed": nn.embedding_init(k_embed, cfg.vocab_size, cfg.d_model, dt),
        "ln0": nn.layernorm_init(cfg.d_model, dt),
        "layers": jax.vmap(lambda k: _layer_init(k, cfg))(keys),
        "final_norm": nn.layernorm_init(cfg.d_model, dt),
        "lm_head": nn.linear_init(k_head, cfg.d_model, cfg.vocab_size, dtype=dt),
    }


# ---------------------------------------------------------------------------
# time mix
# ---------------------------------------------------------------------------


def _ddlerp(tm, x, x_prev):
    """Data-dependent token-shift. x/x_prev: (B, S, D) -> (B, S, 5, D)."""
    xx = x_prev - x
    x_base = x + xx * tm["mu_x"].astype(x.dtype)
    mix = jnp.tanh(nn.linear({"w": tm["A_mix"]}, x_base))
    b, s, _ = mix.shape
    mix = mix.reshape(b, s, N_MIX, -1)
    lora = jnp.einsum("bsfr,frd->bsfd", mix.astype(jnp.float32),
                      tm["B_mix"].astype(jnp.float32))
    mus = tm["mu"].astype(jnp.float32)[None, None]
    return (x[:, :, None] + xx[:, :, None] *
            (mus + lora).astype(x.dtype))


def _decay(tm, xw):
    """Data-dependent per-channel decay in (0, 1). xw: (B, S, D)."""
    lora = jnp.tanh(nn.linear({"w": tm["A_w"]}, xw)).astype(jnp.float32)
    ww = tm["w0"] + lora @ tm["B_w"]
    return jnp.exp(-jnp.exp(ww))  # fp32


def _wkv_scan(r, k, v, w, u, state):
    """Recurrent WKV. r/k/v/w: (B, S, H, n); state: (B, H, n, n).

    Returns (y (B, S, H, n), final state). fp32 state math.
    """
    r, k, v, w = (t.astype(jnp.float32) for t in (r, k, v, w))

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp  # (B, H, n)
        kv = k_t[..., :, None] * v_t[..., None, :]          # (B,H,n,n)
        y = jnp.einsum("bhn,bhnm->bhm", r_t, s + u[None, :, :, None] * kv)
        s = w_t[..., :, None] * s + kv
        return s, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    state, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1), state


_WKV_CLIP = 35.0  # exp-arg clamp for the intra-chunk k rescale (see note)


def _wkv_chunked(r, k, v, w, u, state, chunk: int = 32):
    """Chunk-parallel WKV — mathematically identical to :func:`_wkv_scan`
    but materializes the (n x n) state once per CHUNK instead of per
    token, turning the per-token HBM-bound recurrence into MXU matmuls
    (§Perf hillclimb R1; the baseline scan's state carry traffic is
    2 * B*H*n*n*4B per token per layer — 64x reduced at chunk=32, and the
    intra-chunk work becomes (C x C) x (C x n) matmuls).

    Derivation (per head; S_t = diag(w_t) S_{t-1} + k_t^T v_t;
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)); with L_t = sum_{s<=t} log w_s
    inside a chunk:
       y_t = (r_t e^{L_{t-1}}) S_chunk0
             + sum_{s<t} (r_t e^{L_{t-1}}) . (k_s e^{-L_s}) v_s
             + (r_t . (u k_t)) v_t
       S_next = e^{L_C} S_chunk0 + sum_s (k_s e^{L_C - L_s})^T v_s
    All exponents except -L_s are <= 0 (stable); -L_s is clamped at
    _WKV_CLIP — only pathological decays (w < e^-35 within one chunk)
    are affected (RWKV6 trained decays are far milder; equivalence is
    property-tested against the scan).
    """
    b, s_len, h, n = r.shape
    pad = (-s_len) % chunk
    if pad:
        zp = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zp(r), zp(k), zp(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
    nc = r.shape[1] // chunk
    shp = (b, nc, chunk, h, n)
    rc, kc, vc, wc = (t.astype(jnp.float32).reshape(shp)
                      for t in (r, k, v, w))

    logw = jnp.log(jnp.maximum(wc, 1e-38))
    lcum = jnp.cumsum(logw, axis=2)                  # L_t, <= 0
    lprev = lcum - logw                              # L_{t-1}
    a = rc * jnp.exp(lprev)                          # (B,NC,C,H,n)
    bb = kc * jnp.exp(jnp.minimum(-lcum, _WKV_CLIP))
    scores = jnp.einsum("bcthn,bcshn->bchts", a, bb)  # (B,NC,H,C,C)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    scores = jnp.where(tri[None, None, None], scores, 0.0)
    diag = jnp.einsum("bcthn,bcthn->bcht", rc, kc * u[None, None, None])
    scores = scores + jnp.eye(chunk)[None, None, None] * diag[..., :, None]
    y_intra = jnp.einsum("bchts,bcshn->bcthn", scores, vc)

    l_last = lcum[:, :, -1:]                          # (B,NC,1,H,n)
    kbar = kc * jnp.exp(l_last - lcum)                # <= k, stable
    decay = jnp.exp(l_last[:, :, 0])                  # (B,NC,H,n)

    def chunk_step(s0, inp):
        a_c, kbar_c, v_c, d_c = inp                   # (B,C,H,n)x3,(B,H,n)
        y_state = jnp.einsum("bthn,bhnm->bthm", a_c, s0)
        s_new = d_c[..., :, None] * s0 + \
            jnp.einsum("bthn,bthm->bhnm", kbar_c, v_c)
        return s_new, y_state

    xs = (jnp.moveaxis(a, 1, 0), jnp.moveaxis(kbar, 1, 0),
          jnp.moveaxis(vc, 1, 0), jnp.moveaxis(decay, 1, 0))
    state, y_state = jax.lax.scan(chunk_step, state, xs)
    y = (y_intra + jnp.moveaxis(y_state, 0, 1)).reshape(
        b, nc * chunk, h, n)[:, :s_len]
    return y, state


def _time_mix(tm, cfg: ModelConfig, x, x_prev, state):
    """x: (B, S, D); x_prev: (B, D) shift state; state: (B, H, n, n)."""
    b, s, d = x.shape
    n = cfg.rwkv.head_size
    h = d // n
    prev = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    xs = _ddlerp(tm, x, prev)
    xr, xk, xv, xw, xg = (xs[:, :, i] for i in range(N_MIX))
    r = nn.linear(tm["wr"], xr).reshape(b, s, h, n)
    k = nn.linear(tm["wk"], xk).reshape(b, s, h, n)
    v = nn.linear(tm["wv"], xv).reshape(b, s, h, n)
    g = jax.nn.silu(nn.linear(tm["wg"], xg))
    w = _decay(tm, xw).reshape(b, s, h, n)
    u = tm["u"].astype(jnp.float32)
    if cfg.rwkv.wkv_chunk and s > 1:
        y, state = _wkv_chunked(r, k, v, w, u, state,
                                chunk=cfg.rwkv.wkv_chunk)
    else:
        y, state = _wkv_scan(r, k, v, w, u, state)
    y = nn.groupnorm(tm["ln_x"], y.reshape(b, s, d).astype(x.dtype), groups=h)
    out = nn.linear(tm["wo"], y * g)
    return out, x[:, -1], state


def _channel_mix(cm, x, x_prev):
    prev = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    xx = prev - x
    xk = x + xx * cm["mu_k"].astype(x.dtype)
    xr = x + xx * cm["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(nn.linear(cm["wk"], xk)))
    kv = nn.linear(cm["wv"], k)
    return jax.nn.sigmoid(nn.linear(cm["wr"], xr).astype(jnp.float32)
                          ).astype(x.dtype) * kv, x[:, -1]


def _layer(p, cfg: ModelConfig, x, st):
    """st: {'wkv': (B,H,n,n), 'tm_prev': (B,D), 'cm_prev': (B,D)}."""
    y, tm_prev, wkv = _time_mix(p["tm"], cfg, nn.layernorm(p["ln1"], x),
                                st["tm_prev"], st["wkv"])
    x = x + y
    y, cm_prev = _channel_mix(p["cm"], nn.layernorm(p["ln2"], x),
                              st["cm_prev"])
    x = x + y
    return constrain(x, "batch", "seq", "embed"), \
        {"wkv": wkv, "tm_prev": tm_prev, "cm_prev": cm_prev}


def _zero_state(cfg: ModelConfig, n_layers: int, b: int):
    n = cfg.rwkv.head_size
    h = cfg.d_model // n
    return {
        "wkv": jnp.zeros((n_layers, b, h, n, n), jnp.float32),
        "tm_prev": jnp.zeros((n_layers, b, cfg.d_model), jnp.dtype(cfg.dtype)),
        "cm_prev": jnp.zeros((n_layers, b, cfg.d_model), jnp.dtype(cfg.dtype)),
    }


def forward(params, cfg: ModelConfig, batch, *, train: bool = False,
            inputs_embeds: Optional[jax.Array] = None):
    tokens = batch["tokens"]
    x = nn.embed(params["embed"], tokens) if inputs_embeds is None \
        else inputs_embeds
    x = nn.layernorm(params["ln0"], x)
    x = constrain(x, "batch", "seq", "embed")
    st0 = _zero_state(cfg, cfg.num_layers, x.shape[0])

    layer_fn = _layer
    if cfg.remat and train:
        layer_fn = jax.checkpoint(_layer, static_argnums=(1,),
                                  policy=jax.checkpoint_policies.nothing_saveable)

    def body(x, inp):
        lp, st = inp
        x, _ = layer_fn(lp, cfg, x, st)
        return x, None
    x, _ = jax.lax.scan(body, x, (params["layers"], st0))
    x = nn.layernorm(params["final_norm"], x)
    logits = nn.linear(params["lm_head"], x).astype(jnp.float32)
    return constrain(logits, "batch", "seq", "vocab"), {}


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int,
               batch=None, params=None):
    return _zero_state(cfg, cfg.num_layers, batch_size)


def decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    """O(1)-state decode: tokens (B, 1)."""
    x = nn.embed(params["embed"], tokens)
    x = nn.layernorm(params["ln0"], x)

    def body(x, inp):
        lp, st = inp
        x, new_st = _layer(lp, cfg, x, st)
        return x, new_st
    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    x = nn.layernorm(params["final_norm"], x)
    logits = nn.linear(params["lm_head"], x).astype(jnp.float32)
    return logits, new_cache
