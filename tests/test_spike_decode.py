"""Decoded sparse datapath (kernels/spike_decode.py, DESIGN.md §9).

Pins, in order of the pipeline:
  * the cumsum prefix-compaction is *the* M-lane carry-lookahead decoder
    (Eq. 5): chunking the compacted index stream by M reproduces
    ``core.sparsity.multilane_decode_full``'s per-cycle lane sets
    exactly, for every lane count at once;
  * the pow2 occupancy-bucket schedule matches its numpy twin in
    ``sim.balance_sim`` bit-for-bit, and sorting provably never does
    worse than unsorted row order (the load-balancing claim);
  * decoded-mode outputs are bitwise equal to the dense reference and
    the tile kernel across shapes x sparsities x bias x int8 weights,
    including all-zero rows, ragged per-row occupancy, and the
    binary-attention integer-count lanes;
  * gradients flow through the shared custom VJP identically to dense;
  * whole-model logits are bitwise equal across dense/tile/decoded on
    both spikingformer configs;
  * ``sparse='auto'`` picks tile at coherent sparsity, decoded at
    fine-grained/ragged sparsity, and tile under jit (traced spikes).

Bit-exactness strategy matches tests/test_engine.py: dyadic-grid weights
make fp32 accumulation order-exact, so equality is to the bit, not a
tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container ships the fixed-seed shim
    from _propcheck import given, settings, strategies as st

from repro.core import engine as E
from repro.core import sparsity
from repro.kernels import spike_decode as SD
from repro.kernels.spike_matmul import spike_matmul
from repro.sim import balance_sim

DEC32 = E.EngineConfig(mode="sparse", sparse="decoded",
                       block_m=32, block_n=32, block_k=32)
TILE32 = DEC32.replace(sparse="tile")


def _spikes(key, shape, density):
    return (jax.random.uniform(key, shape) < density).astype(jnp.float32)


def _ragged_spikes(key, m, k, lo=0.0, hi=0.6):
    """Per-row density uniform in [lo, hi] — ragged occupancy, and lo=0
    guarantees (near-)empty rows ride along."""
    k1, k2 = jax.random.split(key)
    dens = jax.random.uniform(k1, (m, 1), minval=lo, maxval=hi)
    return (jax.random.uniform(k2, (m, k)) < dens).astype(jnp.float32)


def _dyadic(key, shape):
    return (jax.random.randint(key, shape, -128, 128)
            .astype(jnp.float32)) * (2.0 ** -8)


# ---------------------------------------------------------------------------
# decode == the Eq. 5 multi-lane decoder
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 64), st.integers(1, 8), st.integers(0, 10 ** 6))
def test_decode_indices_equals_multilane_decoder(n, m_lanes, seed):
    """The compacted index stream, chunked by the lane count, IS the
    carry-lookahead decoder's per-cycle output — for any M."""
    rng = np.random.default_rng(seed)
    bits = rng.random(n) < rng.random()  # random density incl. empty
    idx, occ = SD.decode_indices(jnp.asarray(bits[None], jnp.float32))
    idx, occ = np.asarray(idx[0]), int(occ[0])
    cycles, n_cycles = sparsity.multilane_decode_full(bits, m_lanes)
    assert n_cycles == sparsity.decode_cycles_for_word(occ, m_lanes)
    flat = np.concatenate(cycles) if occ else np.array([], np.int64)
    np.testing.assert_array_equal(idx[:occ], flat)
    for c, cyc in enumerate(cycles):  # per-cycle lane sets, in order
        np.testing.assert_array_equal(
            idx[c * m_lanes: c * m_lanes + len(cyc)], cyc)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 96), st.integers(0, 10 ** 6))
def test_decode_indices_matches_numpy_prefix_compact(n, seed):
    rng = np.random.default_rng(seed)
    bits = rng.random(n) < 0.4
    idx, occ = SD.decode_indices(jnp.asarray(bits[None], jnp.float32))
    ref_idx, ref_pc = sparsity.prefix_compact(bits)
    assert int(occ[0]) == ref_pc
    np.testing.assert_array_equal(np.asarray(idx[0])[:ref_pc], ref_idx)


def test_decode_cap_guards_concrete_truncation():
    s = jnp.ones((4, 16), jnp.float32)
    with pytest.raises(ValueError, match="max row occupancy"):
        SD.decode_indices(s, cap=8)
    idx, occ = SD.decode_indices(s, cap=16)  # exact cap is fine
    np.testing.assert_array_equal(np.asarray(occ), np.full(4, 16))


# ---------------------------------------------------------------------------
# bucket schedule: numpy twin + load-balancing property
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 200), st.sampled_from([8, 16, 32]),
       st.sampled_from([8, 32, 128]), st.integers(1, 300),
       st.integers(0, 10 ** 6))
def test_schedule_matches_balance_sim_twin(m, block_m, c_block, k, seed):
    rng = np.random.default_rng(seed)
    occ = rng.integers(0, k + 1, size=m)
    ref = balance_sim.bucket_schedule(occ, block_m, c_block, cap=k)
    pad = (-m) % block_m
    occ_j = jnp.asarray(np.concatenate([occ, np.zeros(pad, np.int64)]),
                        jnp.int32)
    got = SD.build_schedule(occ_j, block_m, c_block, cap=k)
    assert ref["executed"] == int(got["executed"])
    assert ref["total"] == int(got["total"])
    assert ref["padded_cap"] == got["padded_cap"]
    np.testing.assert_array_equal(ref["caps"], np.asarray(got["caps"]))


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 12), st.integers(0, 10 ** 6))
def test_occupancy_binning_never_loses_to_unsorted(n_groups, seed):
    """The load-balancing claim: binning rows by occupancy (sort) makes
    each group's pow2 capacity tight, so total executed steps are <= any
    unsorted grouping's — no group waits on a stray dense row."""
    rng = np.random.default_rng(seed)
    block_m, c_block, k = 16, 16, 128
    occ = rng.integers(0, k + 1, size=n_groups * block_m)
    sorted_sched = balance_sim.bucket_schedule(occ, block_m, c_block,
                                               cap=k)
    caps_unsorted = np.minimum(balance_sim._pow2ceil(
        occ.reshape(n_groups, block_m).max(axis=1)),
        sorted_sched["padded_cap"])
    unsorted_steps = int((-(-caps_unsorted // c_block)).sum())
    assert sorted_sched["executed"] <= unsorted_steps
    assert sorted_sched["executed"] <= sorted_sched["total"]
    assert sum(sorted_sched["buckets"].values()) == n_groups
    assert all(c == 0 or c == 1 << (c.bit_length() - 1)
               for c in sorted_sched["buckets"])  # pow2 buckets only


def test_predicted_schedule_tracks_measured():
    """The sim's Binomial density model predicts the measured tensor
    schedule to within a step or two (same distribution, different
    draws) — the bench cross-validation in miniature."""
    key = jax.random.PRNGKey(7)
    m, k, d = 256, 128, 0.1
    s = _spikes(key, (m, k), d)
    occ = (s != 0).sum(-1).astype(jnp.int32)
    meas = SD.build_schedule(occ, 32, 32, cap=k)
    pred = balance_sim.predicted_schedule(m, k, d, 32, 32,
                                          np.random.default_rng(0))
    assert pred["total"] == int(meas["total"])
    ratio = pred["executed"] / max(1, int(meas["executed"]))
    assert 0.5 <= ratio <= 2.0


# ---------------------------------------------------------------------------
# decoded == dense == tile, bitwise
# ---------------------------------------------------------------------------

SHAPES = [((2, 2, 32, 64), 48),     # (T, B, L, K), N
          ((4, 1, 48, 96), 80),     # nothing divides 32 evenly
          ((2, 3, 64, 128), 128)]
SPARSITIES = [0.5, 0.8, 0.95]


@pytest.mark.parametrize("lead_k,n", SHAPES)
@pytest.mark.parametrize("sparsity", SPARSITIES)
@pytest.mark.parametrize("bias", [False, True])
def test_decoded_bit_identical_to_dense_and_tile(lead_k, n, sparsity,
                                                 bias):
    ks = jax.random.split(jax.random.PRNGKey(int(sparsity * 100) + n), 3)
    s = _spikes(ks[0], lead_k, 1.0 - sparsity)
    p = {"w": _dyadic(ks[1], (lead_k[-1], n))}
    if bias:
        p["b"] = _dyadic(ks[2], (n,))
    dense = E.spike_linear(p, s, engine=E.DENSE)
    tile = E.spike_linear(p, s, engine=TILE32)
    dec = E.spike_linear(p, s, engine=DEC32)
    assert dec.shape == (*lead_k[:-1], n)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(dec))
    np.testing.assert_array_equal(np.asarray(tile), np.asarray(dec))


@pytest.mark.parametrize("bias", [False, True])
def test_decoded_ragged_and_all_zero_rows(bias):
    """Ragged per-row occupancy (the decoded path's home regime) incl.
    fully dark rows and a fully dense row."""
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    s = _ragged_spikes(ks[0], 96, 160, lo=0.0, hi=0.7)
    s = s.at[5].set(0.0).at[17].set(0.0)        # guaranteed empty rows
    s = s.at[40].set(1.0)                       # one fully dense row
    p = {"w": _dyadic(ks[1], (160, 64))}
    if bias:
        p["b"] = _dyadic(ks[2], (64,))
    dense = E.spike_linear(p, s, engine=E.DENSE)
    dec = E.spike_linear(p, s, engine=DEC32)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(dec))
    # empty rows produce exactly bias (or zero)
    want = np.asarray(p["b"]) if bias else np.zeros(64, np.float32)
    np.testing.assert_array_equal(np.asarray(dec[5]), want)


def test_decoded_all_zero_input():
    s = jnp.zeros((64, 96), jnp.float32)
    w = _dyadic(jax.random.PRNGKey(0), (96, 32))
    dec = E.spike_linear({"w": w}, s, engine=DEC32)
    np.testing.assert_array_equal(np.asarray(dec), np.zeros((64, 32)))
    occ = (s != 0).sum(-1).astype(jnp.int32)
    sched = SD.build_schedule(occ, 32, 32, cap=96)
    assert int(sched["executed"]) == 0  # every grid step skipped


def test_gather_matmul_equals_tile_kernel_on_arbitrary_weights():
    """Both kernels accumulate the same fp32 terms in ascending-k order,
    so on *sequentially accumulated* backends they agree on arbitrary
    normal weights too (the tile kernel only adds exact zeros on top)."""
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    s = _ragged_spikes(ks[0], 80, 128, lo=0.0, hi=0.4)
    w = jax.random.normal(ks[1], (128, 48), jnp.float32)
    tile = spike_matmul(s, w, block_m=16, block_n=16, block_k=128,
                        out_dtype=jnp.float32)
    dec = SD.gather_spike_matmul(s, w, block_m=16, block_n=16,
                                 c_block=128)
    np.testing.assert_array_equal(np.asarray(tile), np.asarray(dec))


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 70), st.integers(1, 90), st.integers(1, 50),
       st.sampled_from([8, 16, 32]), st.integers(0, 10 ** 6))
def test_gather_matmul_random_shapes_and_blocks(m, k, n, block, seed):
    """Shape-robustness sweep: nothing needs to divide anything."""
    ks = jax.random.split(jax.random.PRNGKey(seed % (1 << 30)), 2)
    s = _ragged_spikes(ks[0], m, k, lo=0.0, hi=0.8)
    w = _dyadic(ks[1], (k, n))
    dense = jnp.dot(s, w, preferred_element_type=jnp.float32)
    dec = SD.gather_spike_matmul(s, w, block_m=block, block_n=block,
                                 c_block=block)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(dec))


# ---------------------------------------------------------------------------
# quantized decoded path (int8 codes, int32 accumulation, counts lanes)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bias", [False, True])
@pytest.mark.parametrize("sparsity", [0.5, 0.95])
def test_quant_decoded_bitwise_vs_quant_references(bias, sparsity):
    """int8 decoded == int8 tile == the int-exact dense reference, on
    dyadic scales (DESIGN.md §8 exactness argument, decoded flavor)."""
    from repro.quant.quantize import quantize_weight
    ks = jax.random.split(jax.random.PRNGKey(int(sparsity * 10)), 3)
    s = _spikes(ks[0], (3, 40, 96), 1.0 - sparsity)
    w = jax.random.normal(ks[1], (96, 64), jnp.float32)
    p = quantize_weight(w, "int8", dyadic=True)
    if bias:
        p["b"] = _dyadic(ks[2], (64,))
    dense = E.spike_linear(p, s, engine=E.DENSE)
    tile = E.spike_linear(p, s, engine=TILE32)
    dec = E.spike_linear(p, s, engine=DEC32)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(dec))
    np.testing.assert_array_equal(np.asarray(tile), np.asarray(dec))


def test_quant_decoded_counts_ride_int32_lanes():
    """Binary-attention counts reach 128+ — the decoded quant kernel
    must carry them on int32 lanes like the tile kernel does (an int8
    cast would wrap); pinned against the int-exact dense reference."""
    from repro.quant.quantize import quantize_weight
    ks = jax.random.split(jax.random.PRNGKey(5), 2)
    mask = (jax.random.uniform(ks[0], (48, 96)) < 0.1)
    counts = jnp.where(mask, 200.0, 0.0)  # > 127: wraps in int8
    w = jax.random.normal(ks[1], (96, 32), jnp.float32)
    p = quantize_weight(w, "int8", dyadic=True)
    dense = E.dense_quant_linear(p, counts)
    dec = E.spike_linear(p, counts, engine=DEC32, counts=True)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(dec))


# ---------------------------------------------------------------------------
# gradients through the shared custom VJP
# ---------------------------------------------------------------------------


def test_decoded_gradients_match_dense():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    s = _ragged_spikes(ks[0], 64, 64, lo=0.0, hi=0.5).reshape(2, 2, 16, 64)
    w = _dyadic(ks[1], (64, 48))
    b = _dyadic(ks[2], (48,))

    def grads(engine):
        def f(s, w, b):
            y = E.spike_linear({"w": w, "b": b}, s, engine=engine)
            return (y * y).sum()
        return jax.grad(f, argnums=(0, 1, 2))(s, w, b)

    for gd, gs in zip(grads(E.DENSE), grads(DEC32)):
        np.testing.assert_allclose(np.asarray(gd), np.asarray(gs),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# dispatch: sparse=auto crossover + jit fallback
# ---------------------------------------------------------------------------


def test_resolve_sparse_path_modes():
    auto = E.EngineConfig(mode="sparse", sparse="auto", block_m=32,
                          block_n=32, block_k=32)
    coherent = jnp.zeros((96, 160)).at[:, :32].set(1.0)  # dark tiles
    ragged = _ragged_spikes(jax.random.PRNGKey(0), 96, 160,
                            lo=0.0, hi=0.2)
    assert E.resolve_sparse_path(None, ragged) == "tile"
    assert E.resolve_sparse_path(TILE32, ragged) == "tile"
    assert E.resolve_sparse_path(DEC32, coherent) == "decoded"
    assert E.resolve_sparse_path(auto, coherent) == "tile"
    assert E.resolve_sparse_path(auto, ragged) == "decoded"

    seen = []

    @jax.jit
    def f(s):
        seen.append(E.resolve_sparse_path(auto, s))
        return s

    f(ragged)
    assert seen == ["tile"]  # traced spikes: static fallback


def test_sparse_auto_engine_end_to_end_bitwise():
    """auto dispatch through spike_linear is still bitwise vs dense on
    both regimes (whichever datapath it picks)."""
    auto = E.EngineConfig(mode="sparse", sparse="auto", block_m=32,
                          block_n=32, block_k=32)
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    w = _dyadic(ks[2], (160, 64))
    for s in (_ragged_spikes(ks[0], 96, 160, lo=0.0, hi=0.2),
              jnp.zeros((96, 160)).at[:, :32].set(1.0)):
        dense = E.spike_linear({"w": w}, s, engine=E.DENSE)
        got = E.spike_linear({"w": w}, s, engine=auto)
        np.testing.assert_array_equal(np.asarray(dense), np.asarray(got))


def test_engine_config_validates_sparse_field():
    with pytest.raises(ValueError, match="sparse datapath"):
        E.EngineConfig(sparse="rowwise")


# ---------------------------------------------------------------------------
# whole model: both spikingformer configs, dense == tile == decoded
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["spikingformer-4-256",
                                  "spikingformer-8-512"])
def test_spikingformer_logits_bitwise_across_sparse_paths(arch):
    from repro.configs import get_config
    from repro.models import registry

    cfg = get_config(arch, smoke=True)
    params = registry.init(cfg, jax.random.PRNGKey(0))
    params = jax.tree_util.tree_map(
        lambda a: jnp.round(a * 256) / 256 if a.dtype == jnp.float32 else a,
        params)
    sz = cfg.vision.img_size
    batch = {"images": jax.random.normal(jax.random.PRNGKey(1),
                                         (2, sz, sz, 3)),
             "labels": jnp.zeros((2,), jnp.int32)}
    outs = {}
    for name, eng in [("dense", E.DENSE), ("tile", TILE32),
                      ("decoded", DEC32)]:
        with E.use_engine(eng):
            outs[name], _ = registry.forward(params, cfg, batch)
    np.testing.assert_array_equal(np.asarray(outs["dense"]),
                                  np.asarray(outs["decoded"]))
    np.testing.assert_array_equal(np.asarray(outs["tile"]),
                                  np.asarray(outs["decoded"]))
