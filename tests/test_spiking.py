"""Spiking core: LIF dynamics, surrogate gradients, encodings."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container has no hypothesis; use fixed-seed shim
    from _propcheck import given, settings, strategies as st

from repro.core import spiking as S


@pytest.mark.parametrize("soft_reset", [False, True])
@pytest.mark.parametrize("tau", [2.0, 4.0])
def test_lif_scan_matches_loop(soft_reset, tau):
    cfg = S.SpikingConfig(time_steps=6, tau=tau, soft_reset=soft_reset)
    x = jax.random.normal(jax.random.PRNGKey(0), (6, 3, 8))
    s1, u1 = S.lif_scan(x, cfg)
    s2, u2 = S.lif_loop_reference(x, cfg)
    np.testing.assert_allclose(s1, s2, atol=1e-6)
    np.testing.assert_allclose(u1, u2, atol=1e-5)


def test_spikes_are_binary():
    cfg = S.SpikingConfig(time_steps=4)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 5, 7)) * 3
    s, _ = S.lif_scan(x, cfg)
    vals = np.unique(np.asarray(s))
    assert set(vals).issubset({0.0, 1.0})


def test_soft_reset_conserves_leftover_membrane():
    # soft reset subtracts the threshold: u stays above 0 for big inputs
    cfg = S.SpikingConfig(time_steps=1, soft_reset=True, v_threshold=1.0)
    x = jnp.full((1, 1), 2.5)
    s, u = S.lif_scan(x, cfg)
    assert float(s[0, 0]) == 1.0
    np.testing.assert_allclose(float(u[0]), 2.5 - 1.0, rtol=1e-6)


def test_hard_reset_zeroes_membrane():
    cfg = S.SpikingConfig(time_steps=1, soft_reset=False)
    x = jnp.full((1, 1), 2.5)
    _, u = S.lif_scan(x, cfg)
    assert float(u[0]) == 0.0


def test_surrogate_gradient_shape_and_peak():
    g = jax.grad(lambda v: S.spike(v, 4.0).sum())(jnp.array([-2.0, 0.0, 2.0]))
    assert float(g[1]) == pytest.approx(1.0)  # alpha/4 at 0 with alpha=4
    assert float(g[0]) < float(g[1]) and float(g[2]) < float(g[1])


def test_binarize_threshold_gradient_flows_to_delta():
    f = lambda d: S.binarize(jnp.linspace(-1, 1, 32), d, 4.0).sum()
    g = jax.grad(f)(jnp.asarray(0.1))
    assert np.isfinite(float(g)) and float(g) != 0.0


@settings(max_examples=25, deadline=None)
@given(st.floats(0.0, 1.0), st.integers(1, 8))
def test_rate_encode_statistics(p, t):
    x = jnp.full((64, 64), p)
    s = S.rate_encode(x, t, jax.random.PRNGKey(0))
    assert s.shape == (t, 64, 64)
    assert abs(float(s.mean()) - p) < 0.05


def test_measure_sparsity():
    s = jnp.zeros((10, 10)).at[0, :5].set(1.0)
    assert float(S.measure_sparsity(s)) == pytest.approx(0.95)
